//! Feature-map substrates: synthetic generators calibrated to the paper's
//! Fig. 3 density distributions, and adapters for *real* feature maps
//! produced by the PJRT artifacts (runtime real-feature mode).
//!
//! Why a generator at all: feature sparsity is input-dependent. The paper
//! samples 50 000 ImageNet images; we sample synthetic images (we have no
//! ImageNet) whose post-ReLU density is drawn per-image from the model's
//! calibrated (mean, sigma) and whose non-zeros are *clustered* — Section
//! 6.2 notes "the large data tends to concentrate" in actual CNNs, unlike
//! uniform synthetic patterns — using a two-state Markov chain along the
//! channel axis.

use crate::util::rng::Rng;

use super::tensor::FeatTensor;
use super::{FeatureSubset, LayerDesc, Model};

/// How non-zero positions are laid out inside generated tensors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// i.i.d. Bernoulli(density) per element — the synthetic-model setting
    /// of Fig. 11/12.
    Uniform,
    /// Markov-clustered runs of non-zeros (mean run length `run`): matches
    /// the concentration of real feature maps noted in Section 6.2.
    Clustered { run: f64 },
}

impl Pattern {
    /// Default clustering for "actual model" emulation.
    pub const ACTUAL: Pattern = Pattern::Clustered { run: 3.0 };
}

/// Per-image density draw: truncated Gaussian around the model mean.
pub fn sample_image_density(model: &Model, rng: &mut Rng) -> f64 {
    let z = rng.gen_normal() * 0.7;
    (model.feature_density + z * model.feature_density_sigma).clamp(0.02, 0.98)
}

/// Generate a feature tensor for `layer` at the given density/pattern.
/// Values are positive (post-ReLU) with magnitude in (0, 1].
pub fn generate(
    layer: &LayerDesc,
    density: f64,
    pattern: Pattern,
    seed: u64,
) -> FeatTensor {
    let mut rng = Rng::seed_from_u64(seed ^ 0xfea7);
    let n = layer.in_h * layer.in_w * layer.cin;
    let mut data = vec![0.0f32; n];
    fill_sparse(&mut data, density, pattern, &mut rng);
    FeatTensor::from_vec(1, layer.in_h, layer.in_w, layer.cin, data)
}

/// Fill `data` with non-zeros at `density` under `pattern`.
pub fn fill_sparse(
    data: &mut [f32],
    density: f64,
    pattern: Pattern,
    rng: &mut Rng,
) {
    let density = density.clamp(0.0, 1.0);
    match pattern {
        Pattern::Uniform => {
            for v in data.iter_mut() {
                *v = if rng.gen_f64() < density {
                    rng.gen_range_u64(1, 255) as f32 / 255.0
                } else {
                    0.0
                };
            }
        }
        Pattern::Clustered { run } => {
            // Two-state Markov chain with stationary probability =
            // density and mean non-zero run length = run:
            //   p(stay in nz)  = 1 - 1/run
            //   p(enter nz)    chosen so stationary dist = density
            let run = run.max(1.0);
            let p_exit = 1.0 / run;
            let p_enter = if density >= 1.0 {
                1.0
            } else {
                (density * p_exit / (1.0 - density)).min(1.0)
            };
            let mut nz = rng.gen_f64() < density;
            for v in data.iter_mut() {
                *v = if nz {
                    rng.gen_range_u64(1, 255) as f32 / 255.0
                } else {
                    0.0
                };
                let p = if nz { 1.0 - p_exit } else { p_enter };
                nz = rng.gen_f64() < p;
            }
        }
    }
}

/// The per-image evaluation set for a model/subset: a list of per-image
/// feature densities, as the paper's ImageNet subsets provide.
pub fn image_densities(
    model: &Model,
    subset: FeatureSubset,
    n_images: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x01_0a6e);
    let center = subset.density(model);
    let sigma = if model.feature_density_sigma == 0.0 {
        0.0
    } else {
        model.feature_density_sigma * 0.35 // within-subset spread
    };
    (0..n_images)
        .map(|_| {
            let z = rng.gen_normal() * 0.7;
            (center + z * sigma).clamp(0.02, 0.98)
        })
        .collect()
}

/// Must-be-performed MAC ratio (Fig. 3 bottom): the probability that both
/// operands of a MAC are non-zero. For independent patterns this is
/// `df * dw`; clustering leaves the product unchanged in expectation (it
/// correlates positions *within* a flow, not across flows).
pub fn must_mac_ratio(feature_density: f64, weight_density: f64) -> f64 {
    (feature_density * weight_density).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn uniform_density_converges() {
        let l = LayerDesc::new("t", 64, 64, 64, 3, 3, 64, 1, 1);
        for d in [0.1, 0.39, 0.7] {
            let f = generate(&l, d, Pattern::Uniform, 5);
            assert!((f.density() - d).abs() < 0.02, "want {d} got {}", f.density());
        }
    }

    #[test]
    fn clustered_density_converges() {
        let l = LayerDesc::new("t", 64, 64, 64, 3, 3, 64, 1, 1);
        for d in [0.2, 0.5] {
            let f = generate(&l, d, Pattern::ACTUAL, 5);
            assert!(
                (f.density() - d).abs() < 0.03,
                "want {d} got {}",
                f.density()
            );
        }
    }

    #[test]
    fn clustered_has_longer_runs() {
        let l = LayerDesc::new("t", 32, 32, 64, 3, 3, 64, 1, 1);
        let runs = |f: &FeatTensor| {
            let mut total = 0usize;
            let mut count = 0usize;
            let mut cur = 0usize;
            for v in &f.data {
                if *v != 0.0 {
                    cur += 1;
                } else if cur > 0 {
                    total += cur;
                    count += 1;
                    cur = 0;
                }
            }
            total as f64 / count.max(1) as f64
        };
        let u = generate(&l, 0.4, Pattern::Uniform, 9);
        let c = generate(&l, 0.4, Pattern::ACTUAL, 9);
        assert!(runs(&c) > runs(&u) * 1.3, "{} vs {}", runs(&c), runs(&u));
    }

    #[test]
    fn image_density_subsets_ordered() {
        let m = zoo::alexnet();
        let avg = |v: Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let lo = avg(image_densities(&m, FeatureSubset::MaxSparsity, 200, 1));
        let mid = avg(image_densities(&m, FeatureSubset::Average, 200, 1));
        let hi = avg(image_densities(&m, FeatureSubset::MinSparsity, 200, 1));
        assert!(lo < mid && mid < hi, "{lo} {mid} {hi}");
    }

    #[test]
    fn must_mac_ratio_matches_table_ii_band() {
        // AlexNet: 0.39 * 0.36 ~ 0.14 — the paper's Fig. 3 shows
        // must-MAC ratios concentrated well below 0.3 for all nets.
        let r = must_mac_ratio(0.39, 0.36);
        assert!(r > 0.1 && r < 0.2);
    }

    #[test]
    fn values_are_positive_post_relu() {
        let l = LayerDesc::new("t", 16, 16, 32, 3, 3, 32, 1, 1);
        let f = generate(&l, 0.5, Pattern::Uniform, 2);
        assert!(f.data.iter().all(|v| *v >= 0.0));
    }
}
