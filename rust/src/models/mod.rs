//! CNN model descriptors and workload substrates.
//!
//! The paper evaluates 71 convolutional layers across AlexNet (5), VGG16
//! (13) and ResNet50 (53); [`zoo`] reproduces those exact layer shapes.
//! [`pruning`] generates magnitude-pruned weight tensors at the paper's
//! Table II sparsity levels, and [`features`] generates/derives feature
//! maps with per-image density variation calibrated to Fig. 3.

pub mod features;
pub mod pruning;
pub mod tensor;
pub mod zoo;

/// A single convolutional layer: everything the compiler and simulator
/// need to know about its geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerDesc {
    pub name: String,
    /// Input feature map height/width (square maps throughout the zoo).
    pub in_h: usize,
    pub in_w: usize,
    /// Input channels.
    pub cin: usize,
    /// Kernel spatial size.
    pub kh: usize,
    pub kw: usize,
    /// Output channels (number of kernels).
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
}

impl LayerDesc {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        in_h: usize,
        in_w: usize,
        cin: usize,
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Self {
            name: name.into(),
            in_h,
            in_w,
            cin,
            kh,
            kw,
            cout,
            stride,
            pad,
        }
    }

    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Output positions = convolutions = GEMM rows (M).
    pub fn num_convs(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// GEMM reduction length before group padding (K).
    pub fn k_len(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// K padded so each (kh,kw) tap spans whole channel groups — the
    /// compiler's reshaping granularity (Section 4.1/4.4).
    pub fn k_len_padded(&self) -> usize {
        self.kh * self.kw * crate::compiler::groups::padded_channels(self.cin)
    }

    /// Channel groups per spatial tap.
    pub fn groups_per_tap(&self) -> usize {
        crate::compiler::groups::padded_channels(self.cin) / crate::GROUP_LEN
    }

    /// Total ECOO groups per convolution window.
    pub fn groups_per_conv(&self) -> usize {
        self.kh * self.kw * self.groups_per_tap()
    }

    /// Dense multiply-accumulate count for the layer.
    pub fn macs(&self) -> u64 {
        self.num_convs() as u64 * self.k_len() as u64 * self.cout as u64
    }

    /// Parameter count (weights only; the zoo nets are conv-only views).
    pub fn params(&self) -> u64 {
        (self.kh * self.kw * self.cin * self.cout) as u64
    }

    /// Dense feature-map elements consumed (with padding overlap).
    pub fn input_elems(&self) -> u64 {
        (self.in_h * self.in_w * self.cin) as u64
    }

    pub fn output_elems(&self) -> u64 {
        (self.num_convs() * self.cout) as u64
    }
}

/// A CNN = an ordered list of conv layers plus bookkeeping totals.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<LayerDesc>,
    /// Target weight density (non-zero fraction) after pruning, per
    /// Table II of the paper.
    pub weight_density: f64,
    /// Mean feature density (post-ReLU non-zero fraction), per Table II.
    pub feature_density: f64,
    /// Std-dev of per-image feature density — wider for AlexNet per the
    /// Fig. 3 distributions; drives the max/avg/min bands of Fig. 14.
    pub feature_density_sigma: f64,
    /// Explicit layer-precedence edges (`deps[i]` = indices of layers
    /// that must finish before layer `i` starts). `None` — every
    /// sequential CNN — means the linear chain, exactly the historical
    /// topology ([`crate::serve::LayerDag::from_model`]). The residual
    /// zoo models carry real skip edges here.
    pub deps: Option<Vec<Vec<usize>>>,
    /// Per-layer multiplier applied to *dynamically sampled* feature
    /// densities ([`crate::serve::density`]); empty = all 1.0. The
    /// spiking nets use it for per-timestep event decay. The static
    /// density paths never read it.
    pub density_scale: Vec<f64>,
}

impl Model {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Table I metric: average accesses per parameter by MACs.
    pub fn avg_param_usage(&self) -> f64 {
        self.total_macs() as f64 / self.total_params() as f64
    }

    pub fn layer(&self, name: &str) -> Option<&LayerDesc> {
        self.layers.iter().find(|l| l.name == name)
    }
}

/// Which of the paper's per-image feature-sparsity subsets to evaluate
/// (Section 5.3: ImageNet divided by resulting feature sparsity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureSubset {
    /// Maximum feature sparsity (lowest density) subset.
    MaxSparsity,
    /// Average subset — the default for all headline numbers.
    Average,
    /// Minimum feature sparsity (highest density) subset.
    MinSparsity,
}

impl FeatureSubset {
    /// Effective mean density for a model under this subset.
    pub fn density(&self, model: &Model) -> f64 {
        let d = model.feature_density;
        let s = model.feature_density_sigma;
        match self {
            FeatureSubset::MaxSparsity => (d - s).max(0.02),
            FeatureSubset::Average => d,
            FeatureSubset::MinSparsity => (d + s).min(0.98),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(k: usize, s: usize, p: usize) -> LayerDesc {
        LayerDesc::new("t", 14, 14, 32, k, k, 64, s, p)
    }

    #[test]
    fn out_dims_same_padding() {
        let layer = l(3, 1, 1);
        assert_eq!(layer.out_h(), 14);
        assert_eq!(layer.out_w(), 14);
    }

    #[test]
    fn out_dims_stride2() {
        let layer = l(3, 2, 1);
        assert_eq!(layer.out_h(), 7);
    }

    #[test]
    fn macs_and_params() {
        let layer = l(1, 1, 0);
        assert_eq!(layer.params(), 32 * 64);
        assert_eq!(layer.macs(), (14 * 14) as u64 * 32 * 64);
    }

    #[test]
    fn groups_per_conv_group_padding() {
        // cin=32 -> 2 groups per tap, 3x3 taps -> 18 groups
        let layer = l(3, 1, 1);
        assert_eq!(layer.groups_per_conv(), 18);
        // cin=3 pads to 16 -> 1 group per tap
        let l2 = LayerDesc::new("t", 8, 8, 3, 3, 3, 64, 1, 1);
        assert_eq!(l2.groups_per_tap(), 1);
        assert_eq!(l2.k_len_padded(), 9 * 16);
    }

    #[test]
    fn subset_density_ordering() {
        let m = zoo::alexnet();
        let lo = FeatureSubset::MaxSparsity.density(&m);
        let avg = FeatureSubset::Average.density(&m);
        let hi = FeatureSubset::MinSparsity.density(&m);
        assert!(lo < avg && avg < hi);
    }
}
