//! Minimal dense tensor types for the numeric paths (real-feature mode,
//! verification against the PJRT artifacts, small-model simulation).
//!
//! The cycle simulator itself never touches these for the big zoo nets —
//! it consumes sampled [`crate::compiler::groups::GroupedStream`]s — but
//! S2Net real-feature mode and the quantizer do.

/// NHWC feature tensor (f32).
#[derive(Debug, Clone, PartialEq)]
pub struct FeatTensor {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl FeatTensor {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> Self {
        Self {
            n,
            h,
            w,
            c,
            data: vec![0.0; n * h * w * c],
        }
    }

    pub fn from_vec(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * h * w * c, "shape/data mismatch");
        Self { n, h, w, c, data }
    }

    #[inline]
    pub fn idx(&self, n: usize, y: usize, x: usize, ch: usize) -> usize {
        ((n * self.h + y) * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, n: usize, y: usize, x: usize, ch: usize) -> f32 {
        self.data[self.idx(n, y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, n: usize, y: usize, x: usize, ch: usize, v: f32) {
        let i = self.idx(n, y, x, ch);
        self.data[i] = v;
    }

    /// Padded read: coordinates outside [0,h)x[0,w) return 0 — the conv
    /// padding semantics.
    #[inline]
    pub fn get_padded(&self, n: usize, y: isize, x: isize, ch: usize) -> f32 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0.0
        } else {
            self.get(n, y as usize, x as usize, ch)
        }
    }

    /// Non-zero fraction.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let nz = self.data.iter().filter(|v| **v != 0.0).count();
        nz as f64 / self.data.len() as f64
    }
}

/// HWIO conv weight tensor (f32), matching the JAX artifact layout.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightTensor {
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub data: Vec<f32>,
}

impl WeightTensor {
    pub fn zeros(kh: usize, kw: usize, cin: usize, cout: usize) -> Self {
        Self {
            kh,
            kw,
            cin,
            cout,
            data: vec![0.0; kh * kw * cin * cout],
        }
    }

    pub fn from_vec(
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        data: Vec<f32>,
    ) -> Self {
        assert_eq!(data.len(), kh * kw * cin * cout, "shape/data mismatch");
        Self {
            kh,
            kw,
            cin,
            cout,
            data,
        }
    }

    #[inline]
    pub fn idx(&self, ky: usize, kx: usize, ci: usize, co: usize) -> usize {
        ((ky * self.kw + kx) * self.cin + ci) * self.cout + co
    }

    #[inline]
    pub fn get(&self, ky: usize, kx: usize, ci: usize, co: usize) -> f32 {
        self.data[self.idx(ky, kx, ci, co)]
    }

    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let nz = self.data.iter().filter(|v| **v != 0.0).count();
        nz as f64 / self.data.len() as f64
    }
}

/// Reference conv2d (NHWC x HWIO -> NHWC) with optional ReLU — the Rust
/// oracle used to cross-check the PJRT artifact numerics and the
/// simulator's value-carrying mode.
pub fn conv2d_ref(
    feat: &FeatTensor,
    w: &WeightTensor,
    stride: usize,
    pad: usize,
    relu: bool,
) -> FeatTensor {
    assert!(feat.c <= w.cin, "input channels exceed kernel channels");
    let oh = (feat.h + 2 * pad - w.kh) / stride + 1;
    let ow = (feat.w + 2 * pad - w.kw) / stride + 1;
    let mut out = FeatTensor::zeros(feat.n, oh, ow, w.cout);
    for n in 0..feat.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for co in 0..w.cout {
                    let mut acc = 0.0f32;
                    for ky in 0..w.kh {
                        for kx in 0..w.kw {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            for ci in 0..feat.c {
                                acc += feat.get_padded(n, iy, ix, ci)
                                    * w.get(ky, kx, ci, co);
                            }
                        }
                    }
                    if relu && acc < 0.0 {
                        acc = 0.0;
                    }
                    out.set(n, oy, ox, co, acc);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        let mut f = FeatTensor::zeros(1, 2, 2, 2);
        f.set(0, 0, 0, 0, 1.0);
        f.set(0, 1, 1, 1, -2.0);
        // 1x1 kernel, identity over 2 channels
        let mut w = WeightTensor::zeros(1, 1, 2, 2);
        let i00 = w.idx(0, 0, 0, 0);
        w.data[i00] = 1.0;
        let i11 = w.idx(0, 0, 1, 1);
        w.data[i11] = 1.0;
        let out = conv2d_ref(&f, &w, 1, 0, false);
        assert_eq!(out.get(0, 0, 0, 0), 1.0);
        assert_eq!(out.get(0, 1, 1, 1), -2.0);
        let relu_out = conv2d_ref(&f, &w, 1, 0, true);
        assert_eq!(relu_out.get(0, 1, 1, 1), 0.0);
    }

    #[test]
    fn conv_3x3_known_values() {
        // all-ones 3x3 input, all-ones 3x3 kernel, pad 1: center = 9
        let f = FeatTensor::from_vec(1, 3, 3, 1, vec![1.0; 9]);
        let w = WeightTensor::from_vec(3, 3, 1, 1, vec![1.0; 9]);
        let out = conv2d_ref(&f, &w, 1, 1, false);
        assert_eq!(out.get(0, 1, 1, 0), 9.0);
        assert_eq!(out.get(0, 0, 0, 0), 4.0); // corner sees 2x2
    }

    #[test]
    fn stride_two_output_dims() {
        let f = FeatTensor::zeros(1, 8, 8, 4);
        let w = WeightTensor::zeros(3, 3, 4, 8);
        let out = conv2d_ref(&f, &w, 2, 1, false);
        assert_eq!((out.h, out.w, out.c), (4, 4, 8));
    }

    #[test]
    fn density_counts_zeros() {
        let f = FeatTensor::from_vec(1, 1, 2, 2, vec![0.0, 1.0, 0.0, 2.0]);
        assert!((f.density() - 0.5).abs() < 1e-12);
    }
}
