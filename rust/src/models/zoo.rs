//! The model zoo: exact conv-layer geometry of the paper's three
//! evaluation networks (71 conv layers total — Section 5.2 reports 71
//! layers evaluated: AlexNet 5 + VGG16 13 + ResNet50 53) plus the
//! CIFAR-scale S2Net implemented by the JAX/Pallas artifacts.
//!
//! Sparsity targets come from Table II of the paper:
//!
//! | net      | weight sparsity | feature sparsity |
//! |----------|-----------------|------------------|
//! | AlexNet  | 64%             | 61%              |
//! | VGG16    | 68%             | 72%              |
//! | ResNet50 | 76%             | 66%              |

use super::{LayerDesc, Model};

/// AlexNet's five conv layers (Krizhevsky et al., 2012), ImageNet shapes.
/// conv2/4/5 are the original two-GPU *grouped* convolutions: each kernel
/// sees half the input channels (cin below is per-group), which is what
/// makes the paper's Table I total come out at ~666M MACs / 2.33M params.
pub fn alexnet() -> Model {
    let layers = vec![
        LayerDesc::new("conv1", 224, 224, 3, 11, 11, 96, 4, 2),
        LayerDesc::new("conv2", 27, 27, 48, 5, 5, 256, 1, 2),
        LayerDesc::new("conv3", 13, 13, 256, 3, 3, 384, 1, 1),
        LayerDesc::new("conv4", 13, 13, 192, 3, 3, 384, 1, 1),
        LayerDesc::new("conv5", 13, 13, 192, 3, 3, 256, 1, 1),
    ];
    Model {
        name: "alexnet".into(),
        layers,
        weight_density: 0.36,
        feature_density: 0.39,
        // AlexNet has the widest per-image density spread (Fig. 3), which
        // is why its Fig. 14 error bars are the largest.
        feature_density_sigma: 0.13,
        deps: None,
        density_scale: Vec::new(),
    }
}

/// VGG16's thirteen conv layers (Simonyan & Zisserman, 2014).
pub fn vgg16() -> Model {
    let mut layers = Vec::new();
    let stages: &[(usize, usize, usize, usize)] = &[
        // (spatial, cin of first conv, cout, convs in stage)
        (224, 3, 64, 2),
        (112, 64, 128, 2),
        (56, 128, 256, 3),
        (28, 256, 512, 3),
        (14, 512, 512, 3),
    ];
    for (si, &(hw, cin0, cout, n)) in stages.iter().enumerate() {
        let mut cin = cin0;
        for i in 0..n {
            layers.push(LayerDesc::new(
                format!("conv{}_{}", si + 1, i + 1),
                hw,
                hw,
                cin,
                3,
                3,
                cout,
                1,
                1,
            ));
            cin = cout;
        }
    }
    Model {
        name: "vgg16".into(),
        layers,
        weight_density: 0.32,
        feature_density: 0.28,
        feature_density_sigma: 0.08,
        deps: None,
        density_scale: Vec::new(),
    }
}

/// ResNet50's 53 conv layers (He et al., 2016): the 7x7 stem, 16
/// bottleneck blocks (1x1 / 3x3 / 1x1) and 4 projection shortcuts.
pub fn resnet50() -> Model {
    let mut layers = vec![LayerDesc::new("conv1", 224, 224, 3, 7, 7, 64, 2, 3)];
    // (stage spatial after downsample, bottleneck width, out channels, blocks)
    let stages: &[(usize, usize, usize, usize)] = &[
        (56, 64, 256, 3),
        (28, 128, 512, 4),
        (14, 256, 1024, 6),
        (7, 512, 2048, 3),
    ];
    let mut cin = 64; // stem output channels (after maxpool, 56x56)
    for (si, &(hw, width, cout, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stage = si + 2;
            // First block of stages 3..5 downsamples with stride 2 on the
            // 3x3 (and on the projection shortcut).
            let stride = if b == 0 && si > 0 { 2 } else { 1 };
            let in_hw = if b == 0 && si > 0 { hw * 2 } else { hw };
            layers.push(LayerDesc::new(
                format!("conv{stage}_{}a", b + 1),
                in_hw, in_hw, cin, 1, 1, width, 1, 0,
            ));
            layers.push(LayerDesc::new(
                format!("conv{stage}_{}b", b + 1),
                in_hw, in_hw, width, 3, 3, width, stride, 1,
            ));
            layers.push(LayerDesc::new(
                format!("conv{stage}_{}c", b + 1),
                hw, hw, width, 1, 1, cout, 1, 0,
            ));
            if b == 0 {
                layers.push(LayerDesc::new(
                    format!("conv{stage}_proj"),
                    in_hw, in_hw, cin, 1, 1, cout, stride, 0,
                ));
            }
            cin = cout;
        }
    }
    Model {
        name: "resnet50".into(),
        layers,
        weight_density: 0.24,
        feature_density: 0.34,
        feature_density_sigma: 0.09,
        deps: None,
        density_scale: Vec::new(),
    }
}

/// The CIFAR-scale network implemented by the JAX/Pallas artifacts
/// (python/compile/model.py). Used by the real-feature end-to-end path.
pub fn s2net() -> Model {
    let layers = vec![
        LayerDesc::new("conv1", 32, 32, 3, 3, 3, 32, 1, 1),
        LayerDesc::new("conv2", 32, 32, 32, 3, 3, 32, 2, 1),
        LayerDesc::new("conv3", 16, 16, 32, 3, 3, 64, 1, 1),
        LayerDesc::new("conv4", 16, 16, 64, 1, 1, 64, 1, 0),
    ];
    Model {
        name: "s2net".into(),
        layers,
        weight_density: 0.35,
        feature_density: 0.45,
        feature_density_sigma: 0.10,
        deps: None,
        density_scale: Vec::new(),
    }
}

/// A spiking (event-driven) convolutional network in the style of the
/// `SparseSNN` reference (see SNIPPETS.md): one inference is `T = 4`
/// timestep passes over a 4-layer CIFAR/DVS-scale stack. We unroll the
/// timestep loop into 16 scheduled layers (`conv_t{t}_{i}`) so the
/// serving/cluster schedulers see the real work shape without needing a
/// time dimension. Event rates are very low (mean density ~0.12) and
/// *decay* across timesteps as membrane potentials settle — expressed
/// via `density_scale = 0.6^t`, which the dynamic per-request density
/// sampler multiplies in. Static-density paths treat it like any other
/// chain model at the mean density.
pub fn snn() -> Model {
    let mut layers = Vec::new();
    let mut density_scale = Vec::new();
    for t in 0..4 {
        layers.push(LayerDesc::new(format!("conv_t{t}_1"), 128, 128, 1, 5, 5, 4, 2, 2));
        layers.push(LayerDesc::new(format!("conv_t{t}_2"), 64, 64, 4, 5, 5, 8, 2, 2));
        layers.push(LayerDesc::new(format!("conv_t{t}_3"), 32, 32, 8, 3, 3, 8, 2, 1));
        layers.push(LayerDesc::new(format!("conv_t{t}_4"), 16, 16, 8, 3, 3, 16, 2, 1));
        let decay = 0.6f64.powi(t as i32);
        for _ in 0..4 {
            density_scale.push(decay);
        }
    }
    Model {
        name: "snn".into(),
        layers,
        weight_density: 0.5,
        // Spike rasters are far sparser than ReLU feature maps.
        feature_density: 0.12,
        feature_density_sigma: 0.05,
        deps: None,
        density_scale,
    }
}

/// An 8-layer residual network (CIFAR ResNet-style) whose skip
/// connections are *real* precedence edges: layers 3/5/7 each wait on
/// both the previous layer and the skip source two layers back. This is
/// the zoo's branchy-[`crate::serve::LayerDag`] workload — every other
/// zoo net schedules as a chain.
pub fn resnet8() -> Model {
    let layers = vec![
        LayerDesc::new("stem", 32, 32, 3, 3, 3, 16, 1, 1),
        LayerDesc::new("res1a", 32, 32, 16, 3, 3, 16, 1, 1),
        LayerDesc::new("res1b", 32, 32, 16, 3, 3, 16, 1, 1),
        LayerDesc::new("res2a", 32, 32, 16, 3, 3, 32, 2, 1),
        LayerDesc::new("res2b", 16, 16, 32, 3, 3, 32, 1, 1),
        LayerDesc::new("res3a", 16, 16, 32, 3, 3, 64, 2, 1),
        LayerDesc::new("res3b", 8, 8, 64, 3, 3, 64, 1, 1),
        LayerDesc::new("head", 8, 8, 64, 1, 1, 64, 1, 0),
    ];
    let deps = vec![
        vec![],        // stem
        vec![0],       // res1a
        vec![1],       // res1b
        vec![2, 0],    // res2a: skip from stem
        vec![3],       // res2b
        vec![4, 2],    // res3a: skip from res1b
        vec![5],       // res3b
        vec![6, 4],    // head: skip from res2b
    ];
    Model {
        name: "resnet8".into(),
        layers,
        weight_density: 0.30,
        feature_density: 0.35,
        feature_density_sigma: 0.10,
        deps: Some(deps),
        density_scale: Vec::new(),
    }
}

/// A synthetic AlexNet clone with designated uniform densities — the
/// workload of the paper's sensitivity studies (Fig. 11/12, Section 6.2:
/// "a series of synthetic AlexNet models ... varying the sparsity levels
/// both on features and weights from 10% to 100%").
pub fn synthetic_alexnet(feature_density: f64, weight_density: f64) -> Model {
    let mut m = alexnet();
    m.name = format!(
        "alexnet-syn-f{:.2}-w{:.2}",
        feature_density, weight_density
    );
    m.feature_density = feature_density;
    m.weight_density = weight_density;
    m.feature_density_sigma = 0.0; // designated, not image-dependent
    m
}

/// All three paper networks.
pub fn paper_models() -> Vec<Model> {
    vec![alexnet(), vgg16(), resnet50()]
}

pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "alexnet" => Some(alexnet()),
        "vgg16" => Some(vgg16()),
        "resnet50" => Some(resnet50()),
        "s2net" => Some(s2net()),
        "snn" => Some(snn()),
        "resnet8" => Some(resnet8()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seventy_one_layers_total() {
        // Section 5.2: "66 out of 71 convolution layers we evaluated".
        let total: usize = paper_models().iter().map(|m| m.layers.len()).sum();
        assert_eq!(total, 71);
        assert_eq!(alexnet().layers.len(), 5);
        assert_eq!(vgg16().layers.len(), 13);
        assert_eq!(resnet50().layers.len(), 53);
    }

    #[test]
    fn table1_mac_totals_match_paper() {
        // Table I: AlexNet 666M MACs / 2.33M params; VGG16 15.3G / 14.7M;
        // ResNet50 3.86G / 23.5M. Conv-only counts, so we check the conv
        // share: AlexNet convs ~655M MACs/2.3M params, VGG16 conv
        // ~15.3G/14.7M, ResNet50 ~3.86G/23.5M (FC layers excluded).
        let a = alexnet();
        assert!((a.total_macs() as f64 / 1e6 - 655.0).abs() < 30.0,
            "alexnet MACs {}", a.total_macs());
        let v = vgg16();
        assert!((v.total_macs() as f64 / 1e9 - 15.3).abs() < 0.3,
            "vgg16 MACs {}", v.total_macs());
        let r = resnet50();
        assert!((r.total_macs() as f64 / 1e9 - 3.86).abs() < 0.5,
            "resnet50 MACs {}", r.total_macs());
    }

    #[test]
    fn table1_param_usage_ordering() {
        // Table I "Avg. Usage of Param.": VGG16 (2082) >> AlexNet (572 for
        // full net; higher conv-only) > ResNet50 (336 full net).
        let v = vgg16().avg_param_usage();
        let r = resnet50().avg_param_usage();
        assert!(v > r, "VGG param reuse {v} should exceed ResNet {r}");
    }

    #[test]
    fn resnet_block_chaining_consistent() {
        let r = resnet50();
        // every 1x1a input channel count equals previous block's output
        let c2_1a = r.layer("conv2_1a").unwrap();
        assert_eq!(c2_1a.cin, 64);
        let c3_1a = r.layer("conv3_1a").unwrap();
        assert_eq!(c3_1a.cin, 256);
        let c5_3c = r.layer("conv5_3c").unwrap();
        assert_eq!(c5_3c.cout, 2048);
    }

    #[test]
    fn vgg_spatial_chain() {
        let v = vgg16();
        assert_eq!(v.layer("conv1_1").unwrap().out_h(), 224);
        assert_eq!(v.layer("conv5_3").unwrap().out_h(), 14);
    }

    #[test]
    fn synthetic_densities_applied() {
        let m = synthetic_alexnet(0.3, 0.5);
        assert_eq!(m.feature_density, 0.3);
        assert_eq!(m.weight_density, 0.5);
        assert_eq!(m.feature_density_sigma, 0.0);
        assert_eq!(m.layers.len(), 5);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("vgg16").is_some());
        assert!(by_name("snn").is_some());
        assert!(by_name("resnet8").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn snn_timestep_structure() {
        let m = snn();
        assert_eq!(m.layers.len(), 16);
        assert_eq!(m.density_scale.len(), 16);
        assert!(m.deps.is_none(), "snn schedules as a chain");
        // Timestep decay: scale is constant within a timestep and decays
        // geometrically across them.
        for t in 0..4 {
            let expect = 0.6f64.powi(t as i32);
            for i in 0..4 {
                assert_eq!(m.density_scale[t * 4 + i], expect);
            }
        }
        assert!(m.density_scale[15] < m.density_scale[0]);
        // Layer shapes follow the SparseSNN stack.
        assert_eq!(m.layers[0].in_h, 128);
        assert_eq!(m.layers[0].cin, 1);
        assert_eq!(m.layers[3].cout, 16);
        assert_eq!(m.layer("conv_t3_4").unwrap().out_h(), 8);
    }

    #[test]
    fn resnet8_skip_edges_are_valid() {
        let m = resnet8();
        assert_eq!(m.layers.len(), 8);
        let deps = m.deps.as_ref().expect("resnet8 carries real skip edges");
        assert_eq!(deps.len(), 8);
        // Skip sources sit two layers upstream of the joins.
        assert_eq!(deps[3], vec![2, 0]);
        assert_eq!(deps[5], vec![4, 2]);
        assert_eq!(deps[7], vec![6, 4]);
        // Edges are acyclic by construction (all point backwards).
        for (i, d) in deps.iter().enumerate() {
            for &p in d {
                assert!(p < i, "dep {p} of layer {i} must be upstream");
            }
        }
        assert!(m.density_scale.is_empty());
    }
}
