//! The model zoo: exact conv-layer geometry of the paper's three
//! evaluation networks (71 conv layers total — Section 5.2 reports 71
//! layers evaluated: AlexNet 5 + VGG16 13 + ResNet50 53) plus the
//! CIFAR-scale S2Net implemented by the JAX/Pallas artifacts.
//!
//! Sparsity targets come from Table II of the paper:
//!
//! | net      | weight sparsity | feature sparsity |
//! |----------|-----------------|------------------|
//! | AlexNet  | 64%             | 61%              |
//! | VGG16    | 68%             | 72%              |
//! | ResNet50 | 76%             | 66%              |

use super::{LayerDesc, Model};

/// AlexNet's five conv layers (Krizhevsky et al., 2012), ImageNet shapes.
/// conv2/4/5 are the original two-GPU *grouped* convolutions: each kernel
/// sees half the input channels (cin below is per-group), which is what
/// makes the paper's Table I total come out at ~666M MACs / 2.33M params.
pub fn alexnet() -> Model {
    let layers = vec![
        LayerDesc::new("conv1", 224, 224, 3, 11, 11, 96, 4, 2),
        LayerDesc::new("conv2", 27, 27, 48, 5, 5, 256, 1, 2),
        LayerDesc::new("conv3", 13, 13, 256, 3, 3, 384, 1, 1),
        LayerDesc::new("conv4", 13, 13, 192, 3, 3, 384, 1, 1),
        LayerDesc::new("conv5", 13, 13, 192, 3, 3, 256, 1, 1),
    ];
    Model {
        name: "alexnet".into(),
        layers,
        weight_density: 0.36,
        feature_density: 0.39,
        // AlexNet has the widest per-image density spread (Fig. 3), which
        // is why its Fig. 14 error bars are the largest.
        feature_density_sigma: 0.13,
    }
}

/// VGG16's thirteen conv layers (Simonyan & Zisserman, 2014).
pub fn vgg16() -> Model {
    let mut layers = Vec::new();
    let stages: &[(usize, usize, usize, usize)] = &[
        // (spatial, cin of first conv, cout, convs in stage)
        (224, 3, 64, 2),
        (112, 64, 128, 2),
        (56, 128, 256, 3),
        (28, 256, 512, 3),
        (14, 512, 512, 3),
    ];
    for (si, &(hw, cin0, cout, n)) in stages.iter().enumerate() {
        let mut cin = cin0;
        for i in 0..n {
            layers.push(LayerDesc::new(
                format!("conv{}_{}", si + 1, i + 1),
                hw,
                hw,
                cin,
                3,
                3,
                cout,
                1,
                1,
            ));
            cin = cout;
        }
    }
    Model {
        name: "vgg16".into(),
        layers,
        weight_density: 0.32,
        feature_density: 0.28,
        feature_density_sigma: 0.08,
    }
}

/// ResNet50's 53 conv layers (He et al., 2016): the 7x7 stem, 16
/// bottleneck blocks (1x1 / 3x3 / 1x1) and 4 projection shortcuts.
pub fn resnet50() -> Model {
    let mut layers = vec![LayerDesc::new("conv1", 224, 224, 3, 7, 7, 64, 2, 3)];
    // (stage spatial after downsample, bottleneck width, out channels, blocks)
    let stages: &[(usize, usize, usize, usize)] = &[
        (56, 64, 256, 3),
        (28, 128, 512, 4),
        (14, 256, 1024, 6),
        (7, 512, 2048, 3),
    ];
    let mut cin = 64; // stem output channels (after maxpool, 56x56)
    for (si, &(hw, width, cout, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stage = si + 2;
            // First block of stages 3..5 downsamples with stride 2 on the
            // 3x3 (and on the projection shortcut).
            let stride = if b == 0 && si > 0 { 2 } else { 1 };
            let in_hw = if b == 0 && si > 0 { hw * 2 } else { hw };
            layers.push(LayerDesc::new(
                format!("conv{stage}_{}a", b + 1),
                in_hw, in_hw, cin, 1, 1, width, 1, 0,
            ));
            layers.push(LayerDesc::new(
                format!("conv{stage}_{}b", b + 1),
                in_hw, in_hw, width, 3, 3, width, stride, 1,
            ));
            layers.push(LayerDesc::new(
                format!("conv{stage}_{}c", b + 1),
                hw, hw, width, 1, 1, cout, 1, 0,
            ));
            if b == 0 {
                layers.push(LayerDesc::new(
                    format!("conv{stage}_proj"),
                    in_hw, in_hw, cin, 1, 1, cout, stride, 0,
                ));
            }
            cin = cout;
        }
    }
    Model {
        name: "resnet50".into(),
        layers,
        weight_density: 0.24,
        feature_density: 0.34,
        feature_density_sigma: 0.09,
    }
}

/// The CIFAR-scale network implemented by the JAX/Pallas artifacts
/// (python/compile/model.py). Used by the real-feature end-to-end path.
pub fn s2net() -> Model {
    let layers = vec![
        LayerDesc::new("conv1", 32, 32, 3, 3, 3, 32, 1, 1),
        LayerDesc::new("conv2", 32, 32, 32, 3, 3, 32, 2, 1),
        LayerDesc::new("conv3", 16, 16, 32, 3, 3, 64, 1, 1),
        LayerDesc::new("conv4", 16, 16, 64, 1, 1, 64, 1, 0),
    ];
    Model {
        name: "s2net".into(),
        layers,
        weight_density: 0.35,
        feature_density: 0.45,
        feature_density_sigma: 0.10,
    }
}

/// A synthetic AlexNet clone with designated uniform densities — the
/// workload of the paper's sensitivity studies (Fig. 11/12, Section 6.2:
/// "a series of synthetic AlexNet models ... varying the sparsity levels
/// both on features and weights from 10% to 100%").
pub fn synthetic_alexnet(feature_density: f64, weight_density: f64) -> Model {
    let mut m = alexnet();
    m.name = format!(
        "alexnet-syn-f{:.2}-w{:.2}",
        feature_density, weight_density
    );
    m.feature_density = feature_density;
    m.weight_density = weight_density;
    m.feature_density_sigma = 0.0; // designated, not image-dependent
    m
}

/// All three paper networks.
pub fn paper_models() -> Vec<Model> {
    vec![alexnet(), vgg16(), resnet50()]
}

pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "alexnet" => Some(alexnet()),
        "vgg16" => Some(vgg16()),
        "resnet50" => Some(resnet50()),
        "s2net" => Some(s2net()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seventy_one_layers_total() {
        // Section 5.2: "66 out of 71 convolution layers we evaluated".
        let total: usize = paper_models().iter().map(|m| m.layers.len()).sum();
        assert_eq!(total, 71);
        assert_eq!(alexnet().layers.len(), 5);
        assert_eq!(vgg16().layers.len(), 13);
        assert_eq!(resnet50().layers.len(), 53);
    }

    #[test]
    fn table1_mac_totals_match_paper() {
        // Table I: AlexNet 666M MACs / 2.33M params; VGG16 15.3G / 14.7M;
        // ResNet50 3.86G / 23.5M. Conv-only counts, so we check the conv
        // share: AlexNet convs ~655M MACs/2.3M params, VGG16 conv
        // ~15.3G/14.7M, ResNet50 ~3.86G/23.5M (FC layers excluded).
        let a = alexnet();
        assert!((a.total_macs() as f64 / 1e6 - 655.0).abs() < 30.0,
            "alexnet MACs {}", a.total_macs());
        let v = vgg16();
        assert!((v.total_macs() as f64 / 1e9 - 15.3).abs() < 0.3,
            "vgg16 MACs {}", v.total_macs());
        let r = resnet50();
        assert!((r.total_macs() as f64 / 1e9 - 3.86).abs() < 0.5,
            "resnet50 MACs {}", r.total_macs());
    }

    #[test]
    fn table1_param_usage_ordering() {
        // Table I "Avg. Usage of Param.": VGG16 (2082) >> AlexNet (572 for
        // full net; higher conv-only) > ResNet50 (336 full net).
        let v = vgg16().avg_param_usage();
        let r = resnet50().avg_param_usage();
        assert!(v > r, "VGG param reuse {v} should exceed ResNet {r}");
    }

    #[test]
    fn resnet_block_chaining_consistent() {
        let r = resnet50();
        // every 1x1a input channel count equals previous block's output
        let c2_1a = r.layer("conv2_1a").unwrap();
        assert_eq!(c2_1a.cin, 64);
        let c3_1a = r.layer("conv3_1a").unwrap();
        assert_eq!(c3_1a.cin, 256);
        let c5_3c = r.layer("conv5_3c").unwrap();
        assert_eq!(c5_3c.cout, 2048);
    }

    #[test]
    fn vgg_spatial_chain() {
        let v = vgg16();
        assert_eq!(v.layer("conv1_1").unwrap().out_h(), 224);
        assert_eq!(v.layer("conv5_3").unwrap().out_h(), 14);
    }

    #[test]
    fn synthetic_densities_applied() {
        let m = synthetic_alexnet(0.3, 0.5);
        assert_eq!(m.feature_density, 0.3);
        assert_eq!(m.weight_density, 0.5);
        assert_eq!(m.feature_density_sigma, 0.0);
        assert_eq!(m.layers.len(), 5);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("vgg16").is_some());
        assert!(by_name("nope").is_none());
    }
}
