//! Weight generation + magnitude pruning.
//!
//! The paper trains its sparse models with Han et al.'s prune-retrain
//! pipeline ([11]) via the neural-network distiller [40]. We do not have
//! ImageNet or a training budget (see DESIGN.md §Hardware-substitution),
//! so we generate Gaussian weights and magnitude-prune them to the exact
//! Table II density — the property the simulator actually consumes is the
//! *non-zero pattern statistics*, which magnitude pruning of a Gaussian
//! matches well for unstructured pruning (zeros spread irregularly, no
//! structural pattern — precisely the irregularity S2Engine targets).

use crate::util::rng::{hash_seed, Rng};

use super::tensor::WeightTensor;
use super::LayerDesc;

/// Deterministic per-(seed, layer) RNG so every component (compiler,
/// simulator, runtime verification) sees identical weights.
pub fn layer_rng(seed: u64, layer_name: &str) -> Rng {
    Rng::seed_from_u64(hash_seed(seed, layer_name))
}

/// Generate He-initialized weights for a layer.
pub fn random_weights(layer: &LayerDesc, seed: u64) -> WeightTensor {
    let mut rng = layer_rng(seed, &layer.name);
    let fan_in = (layer.kh * layer.kw * layer.cin) as f64;
    let std = (2.0 / fan_in).sqrt();
    let n = layer.kh * layer.kw * layer.cin * layer.cout;
    let data: Vec<f32> = (0..n).map(|_| (rng.gen_normal() * std) as f32).collect();
    WeightTensor::from_vec(layer.kh, layer.kw, layer.cin, layer.cout, data)
}

/// Magnitude-prune `w` in place to the target density (non-zero
/// fraction): the smallest-|w| elements are zeroed, exactly the
/// unstructured criterion of Han et al. [11].
pub fn magnitude_prune(w: &mut WeightTensor, density: f64) {
    let density = density.clamp(0.0, 1.0);
    let keep = ((w.data.len() as f64) * density).round() as usize;
    if keep >= w.data.len() {
        return;
    }
    if keep == 0 {
        w.data.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    // threshold = keep-th largest magnitude
    let idx = mags.len() - keep;
    mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    let thresh = mags[idx];
    // Values strictly above the threshold always survive; threshold ties
    // survive in scan order until the keep quota is exact.
    let above = w.data.iter().filter(|v| v.abs() > thresh).count();
    let mut tie_quota = keep - above;
    for v in w.data.iter_mut() {
        let a = v.abs();
        if a > thresh {
            continue;
        }
        if a == thresh && a != 0.0 && tie_quota > 0 {
            tie_quota -= 1;
            continue;
        }
        *v = 0.0;
    }
}

/// Generate-and-prune in one step, to the model's Table II density.
pub fn pruned_weights(layer: &LayerDesc, density: f64, seed: u64) -> WeightTensor {
    let mut w = random_weights(layer, seed);
    magnitude_prune(&mut w, density);
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn small_layer() -> LayerDesc {
        LayerDesc::new("t", 8, 8, 32, 3, 3, 64, 1, 1)
    }

    #[test]
    fn deterministic_generation() {
        let l = small_layer();
        let a = random_weights(&l, 7);
        let b = random_weights(&l, 7);
        assert_eq!(a.data, b.data);
        let c = random_weights(&l, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn prune_hits_target_density() {
        let l = small_layer();
        for target in [0.1, 0.25, 0.36, 0.5, 0.9] {
            let w = pruned_weights(&l, target, 3);
            let got = w.density();
            assert!(
                (got - target).abs() < 0.02,
                "target {target} got {got}"
            );
        }
    }

    #[test]
    fn prune_keeps_largest() {
        let mut w = WeightTensor::from_vec(
            1,
            1,
            2,
            2,
            vec![0.1, -5.0, 0.2, 3.0],
        );
        magnitude_prune(&mut w, 0.5);
        assert_eq!(w.data[0], 0.0);
        assert_eq!(w.data[1], -5.0);
        assert_eq!(w.data[2], 0.0);
        assert_eq!(w.data[3], 3.0);
    }

    #[test]
    fn prune_extremes() {
        let l = small_layer();
        let w0 = pruned_weights(&l, 0.0, 1);
        assert_eq!(w0.density(), 0.0);
        let w1 = pruned_weights(&l, 1.0, 1);
        assert!(w1.density() > 0.999);
    }

    #[test]
    fn paper_density_on_real_layers() {
        let m = zoo::alexnet();
        let w = pruned_weights(&m.layers[2], m.weight_density, 42);
        assert!((w.density() - 0.36).abs() < 0.01);
    }
}
