//! FB/WB SRAM capacity model — the Section 5.2 provisioning analysis.
//!
//! The paper sizes the naive array with 2 MB of SRAM ("sufficient to hold
//! 66 out of 71 convolution layers we evaluated") and S²Engine with 1 MB
//! ("sufficient … to hold 68 out of 71 layers", thanks to ECOO
//! compression + CE-array overlap reuse). This module computes, per
//! layer, the working set each design must keep resident and whether it
//! fits, reproducing those two counts.

use crate::compiler::groups::padded_channels;
use crate::models::{LayerDesc, Model};
use crate::GROUP_LEN;

/// Resident working set of one layer, in bytes, for both designs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkingSet {
    /// Naive array: uncompressed 8-bit features with per-row im2col
    /// copies (no overlap reuse — Section 3.1) + dense weights.
    pub naive_bytes: u64,
    /// S²Engine: ECOO-compressed features stored once (CE array
    /// materializes the overlap on-chip) + compressed weights.
    pub s2_bytes: u64,
}

/// Expected compressed bytes of a dense tensor at `density` with the
/// 13/14-bit ECOO token widths, including one placeholder per all-zero
/// group (binomial probability of an empty group).
pub fn ecoo_bytes(elems: u64, density: f64, token_bits: u32) -> u64 {
    let nnz = elems as f64 * density;
    let groups = elems as f64 / GROUP_LEN as f64;
    // probability a 16-slot group is entirely zero
    let p_empty = (1.0 - density).powi(GROUP_LEN as i32);
    let placeholders = groups * p_empty;
    (((nnz + placeholders) * token_bits as f64) / 8.0).ceil() as u64
}

/// Reference kernel-tile width for WB provisioning: weights stream from
/// DRAM one column-tile at a time (double-buffered 32-kernel tiles — the
/// paper's SCNN-comparison array width), so WB holds at most this many
/// kernels, while FB must hold the whole input + output maps for the
/// layer to run without DRAM re-reads.
pub const WB_TILE_KERNELS: usize = 64;

/// Working set of `layer` at the given densities: input feature map +
/// output feature map (layer pipelining) + one double-buffered
/// kernel-tile of weights.
pub fn working_set(layer: &LayerDesc, feature_density: f64, weight_density: f64) -> WorkingSet {
    let input = layer.input_elems();
    let output = layer.output_elems();
    let tile_kernels = layer.cout.min(WB_TILE_KERNELS) as u64;
    let weights_dense = (layer.kh * layer.kw * layer.cin) as u64 * tile_kernels;

    // naive: dense 8-bit in+out maps + the resident weight tile
    let naive_bytes = input + output + weights_dense;

    // S2: compressed in+out stored once (the CE array materializes the
    // overlap on-chip) + compressed weight tile; padded channels compress
    // to placeholders (accounted at proportionally reduced density).
    let padded_elems =
        (layer.in_h * layer.in_w * padded_channels(layer.cin)) as u64;
    let eff_density = feature_density * layer.cin as f64
        / padded_channels(layer.cin) as f64;
    let f_in = ecoo_bytes(padded_elems, eff_density, 13);
    let f_out = ecoo_bytes(output, feature_density, 13);
    let w_padded = (layer.kh * layer.kw * padded_channels(layer.cin)) as u64
        * tile_kernels;
    let w_density = weight_density * layer.cin as f64
        / padded_channels(layer.cin) as f64;
    let w_bytes = ecoo_bytes(w_padded, w_density, 14);
    WorkingSet {
        naive_bytes,
        s2_bytes: f_in + f_out + w_bytes,
    }
}

/// Per-model fit counts: how many layers fit the given capacities.
#[derive(Debug, Clone, PartialEq)]
pub struct FitReport {
    pub model: String,
    pub layers_total: usize,
    pub naive_fits: usize,
    pub s2_fits: usize,
    /// Names of layers that do NOT fit each budget.
    pub naive_spills: Vec<String>,
    pub s2_spills: Vec<String>,
}

/// Evaluate fit for one model against the paper's budgets.
pub fn fit_report(model: &Model, naive_cap: u64, s2_cap: u64) -> FitReport {
    let mut r = FitReport {
        model: model.name.clone(),
        layers_total: model.layers.len(),
        naive_fits: 0,
        s2_fits: 0,
        naive_spills: Vec::new(),
        s2_spills: Vec::new(),
    };
    for l in &model.layers {
        let ws = working_set(l, model.feature_density, model.weight_density);
        if ws.naive_bytes <= naive_cap {
            r.naive_fits += 1;
        } else {
            r.naive_spills.push(l.name.clone());
        }
        if ws.s2_bytes <= s2_cap {
            r.s2_fits += 1;
        } else {
            r.s2_spills.push(l.name.clone());
        }
    }
    r
}

/// The paper's Section 5.2 claim across all 71 evaluated layers:
/// (naive fits @2MB, s2 fits @1MB, total).
pub fn paper_fit_counts() -> (usize, usize, usize) {
    let models = crate::models::zoo::paper_models();
    let mut naive = 0;
    let mut s2 = 0;
    let mut total = 0;
    for m in &models {
        let r = fit_report(m, 2 << 20, 1 << 20);
        naive += r.naive_fits;
        s2 += r.s2_fits;
        total += r.layers_total;
    }
    (naive, s2, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn ecoo_bytes_monotone_in_density() {
        let lo = ecoo_bytes(100_000, 0.2, 13);
        let hi = ecoo_bytes(100_000, 0.8, 13);
        assert!(lo < hi);
        // dense costs 13/8 bytes per element
        let dense = ecoo_bytes(100_000, 1.0, 13);
        assert!((dense as f64 - 100_000.0 * 13.0 / 8.0).abs() < 16.0);
    }

    #[test]
    fn ecoo_bytes_counts_placeholders() {
        // at density 0 every group still stores one placeholder token
        let b = ecoo_bytes(1600, 0.0, 13);
        assert_eq!(b, (100.0f64 * 13.0 / 8.0).ceil() as u64);
    }

    #[test]
    fn s2_working_set_smaller_than_naive_for_3x3() {
        let m = zoo::vgg16();
        for l in &m.layers {
            let ws = working_set(l, m.feature_density, m.weight_density);
            assert!(
                ws.s2_bytes < ws.naive_bytes,
                "{}: s2 {} vs naive {}",
                l.name,
                ws.s2_bytes,
                ws.naive_bytes
            );
        }
    }

    #[test]
    fn paper_fit_counts_close_to_66_and_68() {
        // Section 5.2: 2 MB holds 66/71 for the naive array; 1 MB holds
        // 68/71 for S2Engine. Our working-set model must land within a
        // couple of layers of both counts.
        let (naive, s2, total) = paper_fit_counts();
        assert_eq!(total, 71);
        assert!(
            (naive as i64 - 66).abs() <= 3,
            "naive fits {naive} (paper 66)"
        );
        assert!((s2 as i64 - 68).abs() <= 3, "s2 fits {s2} (paper 68)");
        assert!(s2 >= naive, "compression must fit at least as many");
    }

    #[test]
    fn spill_lists_name_big_early_layers() {
        let m = zoo::vgg16();
        let r = fit_report(&m, 2 << 20, 1 << 20);
        // VGG's big 224x224 layers are the classic spillers
        assert!(
            r.naive_spills.iter().any(|n| n.starts_with("conv1")
                || n.starts_with("conv2")),
            "spills: {:?}",
            r.naive_spills
        );
    }
}
