//! Event counters collected by the cycle simulator — the raw material for
//! every metric the paper reports: cycles → speed (Figs. 10/11/14),
//! component events → energy (Figs. 15/16, via [`crate::energy`]), buffer
//! traffic → memory efficiency (Fig. 13).

/// Counters for one simulated tile (one array pass).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TileStats {
    /// DS-clock cycles until every PE finished and every result drained.
    pub ds_cycles: u64,
    /// 8-bit MAC operations actually performed (must-MACs incl. the
    /// 16-bit partial-product expansion).
    pub mac_ops: u64,
    /// Aligned pairs emitted by DS components.
    pub pairs: u64,
    /// Dense MACs this tile covers (what the naive array would compute).
    pub dense_macs: u64,
    /// Tokens pushed between PEs (inter-PE FIFO traffic; energy events).
    pub token_pushes: u64,
    /// DS cycles lost because the WF-FIFO was full (MAC-bound stall).
    pub stall_wf_full: u64,
    /// DS cycles lost because a downstream W/F-FIFO was full.
    pub stall_out_full: u64,
    /// DS cycles a PE sat idle waiting for input tokens.
    pub stall_starved: u64,
    /// MAC-clock cycles the MAC units sat idle (utilization metric).
    pub mac_idle: u64,
    /// Feature-buffer group reads issued *without* CE reuse (every
    /// reference loads from FB — the naive arrangement of Fig. 8 top).
    pub fb_reads_no_ce: u64,
    /// Feature-buffer group reads with CE reuse (distinct groups only;
    /// repeats come from neighbouring CE FIFOs).
    pub fb_reads_ce: u64,
    /// CE-internal FIFO accesses that replaced FB reads.
    pub ce_fifo_reads: u64,
    /// Weight-buffer group reads.
    pub wb_reads: u64,
    /// Feature tokens injected (for DRAM/SRAM traffic accounting).
    pub f_tokens: u64,
    /// Weight tokens injected.
    pub w_tokens: u64,
    /// Result values drained (one per active PE).
    pub results: u64,
    /// DS cycles spent on group-barrier synchronisation.
    pub barrier_cycles: u64,
}

impl TileStats {
    pub fn merge(&mut self, o: &TileStats) {
        self.ds_cycles += o.ds_cycles;
        self.mac_ops += o.mac_ops;
        self.pairs += o.pairs;
        self.dense_macs += o.dense_macs;
        self.token_pushes += o.token_pushes;
        self.stall_wf_full += o.stall_wf_full;
        self.stall_out_full += o.stall_out_full;
        self.stall_starved += o.stall_starved;
        self.mac_idle += o.mac_idle;
        self.fb_reads_no_ce += o.fb_reads_no_ce;
        self.fb_reads_ce += o.fb_reads_ce;
        self.ce_fifo_reads += o.ce_fifo_reads;
        self.wb_reads += o.wb_reads;
        self.f_tokens += o.f_tokens;
        self.w_tokens += o.w_tokens;
        self.results += o.results;
        self.barrier_cycles += o.barrier_cycles;
    }

    /// Scale all extrapolatable counters by `k` (tile-sampling
    /// extrapolation: `k = n_tiles / n_sampled`). Cycle counts scale
    /// linearly because tiles execute back-to-back on one array.
    pub fn scaled(&self, k: f64) -> TileStats {
        let s = |v: u64| (v as f64 * k).round() as u64;
        TileStats {
            ds_cycles: s(self.ds_cycles),
            mac_ops: s(self.mac_ops),
            pairs: s(self.pairs),
            dense_macs: s(self.dense_macs),
            token_pushes: s(self.token_pushes),
            stall_wf_full: s(self.stall_wf_full),
            stall_out_full: s(self.stall_out_full),
            stall_starved: s(self.stall_starved),
            mac_idle: s(self.mac_idle),
            fb_reads_no_ce: s(self.fb_reads_no_ce),
            fb_reads_ce: s(self.fb_reads_ce),
            ce_fifo_reads: s(self.ce_fifo_reads),
            wb_reads: s(self.wb_reads),
            f_tokens: s(self.f_tokens),
            w_tokens: s(self.w_tokens),
            results: s(self.results),
            barrier_cycles: s(self.barrier_cycles),
        }
    }

    /// First field (name, self-value, other-value) on which two stats
    /// disagree — diagnostics for the engine-equivalence suite, which
    /// requires every field to match bit-for-bit.
    pub fn first_difference(&self, o: &TileStats) -> Option<(&'static str, u64, u64)> {
        let fields: [(&'static str, u64, u64); 17] = [
            ("ds_cycles", self.ds_cycles, o.ds_cycles),
            ("mac_ops", self.mac_ops, o.mac_ops),
            ("pairs", self.pairs, o.pairs),
            ("dense_macs", self.dense_macs, o.dense_macs),
            ("token_pushes", self.token_pushes, o.token_pushes),
            ("stall_wf_full", self.stall_wf_full, o.stall_wf_full),
            ("stall_out_full", self.stall_out_full, o.stall_out_full),
            ("stall_starved", self.stall_starved, o.stall_starved),
            ("mac_idle", self.mac_idle, o.mac_idle),
            ("fb_reads_no_ce", self.fb_reads_no_ce, o.fb_reads_no_ce),
            ("fb_reads_ce", self.fb_reads_ce, o.fb_reads_ce),
            ("ce_fifo_reads", self.ce_fifo_reads, o.ce_fifo_reads),
            ("wb_reads", self.wb_reads, o.wb_reads),
            ("f_tokens", self.f_tokens, o.f_tokens),
            ("w_tokens", self.w_tokens, o.w_tokens),
            ("results", self.results, o.results),
            ("barrier_cycles", self.barrier_cycles, o.barrier_cycles),
        ];
        fields.into_iter().find(|(_, a, b)| a != b)
    }

    /// Sparse skip efficiency: fraction of dense MACs eliminated.
    pub fn skip_ratio(&self) -> f64 {
        if self.dense_macs == 0 {
            return 0.0;
        }
        1.0 - self.mac_ops as f64 / self.dense_macs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds() {
        let mut a = TileStats {
            ds_cycles: 10,
            mac_ops: 5,
            ..Default::default()
        };
        let b = TileStats {
            ds_cycles: 7,
            mac_ops: 2,
            fb_reads_ce: 3,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.ds_cycles, 17);
        assert_eq!(a.mac_ops, 7);
        assert_eq!(a.fb_reads_ce, 3);
    }

    #[test]
    fn scaled_multiplies() {
        let a = TileStats {
            ds_cycles: 10,
            dense_macs: 100,
            mac_ops: 40,
            ..Default::default()
        };
        let b = a.scaled(2.5);
        assert_eq!(b.ds_cycles, 25);
        assert_eq!(b.dense_macs, 250);
    }

    #[test]
    fn first_difference_names_the_field() {
        let a = TileStats {
            ds_cycles: 10,
            mac_ops: 5,
            ..Default::default()
        };
        let mut b = a;
        assert_eq!(a.first_difference(&b), None);
        b.stall_starved = 7;
        assert_eq!(a.first_difference(&b), Some(("stall_starved", 0, 7)));
    }

    #[test]
    fn skip_ratio() {
        let a = TileStats {
            dense_macs: 100,
            mac_ops: 25,
            ..Default::default()
        };
        assert!((a.skip_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(TileStats::default().skip_ratio(), 0.0);
    }
}
