//! Reference tile simulator: the original full-array sweep engine.
//!
//! Every DS cycle steps *all* R×C PEs in reverse raster order, whether or
//! not they can make progress. This is the simplest faithful encoding of
//! the Section 4.1/4.3 semantics and is retained as the oracle for the
//! event-driven engine in [`super::array`]: the randomized equivalence
//! suite (`tests/sim_equivalence.rs`) asserts the two produce bit-identical
//! [`TileStats`] for the same tile. Keep this implementation boring and
//! obviously correct — the fast engine is the one allowed to be clever.

use super::ce;
use super::pe::Pe;
use super::stats::TileStats;
use crate::compiler::mapping::TileJob;
use crate::config::ArrayConfig;

/// Hard safety limit: no realistic tile needs this many DS cycles; hitting
/// it means a dataflow deadlock (a bug), so we panic loudly.
pub(crate) const CYCLE_LIMIT: u64 = 50_000_000;

/// Simulate one tile with the full-sweep reference engine.
pub fn simulate_tile_reference(
    tile: &TileJob,
    cfg: &ArrayConfig,
    ce_enabled: bool,
) -> TileStats {
    let rows = tile.active_rows();
    let cols = tile.active_cols();
    assert!(rows > 0 && cols > 0, "empty tile");
    assert!(
        rows <= cfg.rows && cols <= cfg.cols,
        "tile {}x{} exceeds array {}x{}",
        rows,
        cols,
        cfg.rows,
        cfg.cols
    );
    let ratio = cfg.ds_ratio.max(1) as u64;
    let n_groups = tile.n_groups as u32;

    let mut stats = TileStats::default();
    stats.dense_macs = tile.dense_macs();
    stats.results = (rows * cols) as u64;

    // Flatten the streams (EOK on weight kernels).
    let f_src: Vec<Vec<u32>> = tile
        .features
        .iter()
        .map(|s| s.to_flow(false).tokens.iter().map(|t| t.0).collect())
        .collect();
    let w_src: Vec<Vec<u32>> = tile
        .weights
        .iter()
        .map(|s| s.to_flow(true).tokens.iter().map(|t| t.0).collect())
        .collect();
    let mut f_idx = vec![0usize; rows];
    let mut w_idx = vec![0usize; cols];

    let mut pes: Vec<Pe> = (0..rows * cols)
        .map(|_| Pe::new(cfg.fifo, n_groups))
        .collect();

    let mut ds_cycle: u64 = 0;
    // MAC tick countdown instead of `ds_cycle % ratio` (ISSUE 1 satellite:
    // no div/mod in the per-cycle loop). Reaches 0 exactly on the cycles
    // where `ds_cycle % ratio == ratio - 1` held.
    let mut mac_countdown = ratio;
    let mut remaining = rows * cols;
    while remaining > 0 {
        // 1. Source injection: the CE array (features) and WB (weights)
        //    deliver one token per DS cycle per edge PE — Section 4.4:
        //    "The CE array runs at the same frequency as DS component".
        for r in 0..rows {
            if f_idx[r] < f_src[r].len() && pes[r * cols].f_fifo.has_space() {
                pes[r * cols].f_fifo.push(f_src[r][f_idx[r]]);
                f_idx[r] += 1;
                stats.f_tokens += 1;
            }
        }
        for c in 0..cols {
            if w_idx[c] < w_src[c].len() && pes[c].w_fifo.has_space() {
                pes[c].w_fifo.push(w_src[c][w_idx[c]]);
                w_idx[c] += 1;
                stats.w_tokens += 1;
            }
        }

        // 2. DS steps in reverse raster order so a token forwarded this
        //    cycle cannot hop multiple PEs within the same cycle.
        let mut idx = rows * cols;
        for r in (0..rows).rev() {
            for c in (0..cols).rev() {
                idx -= 1;
                if pes[idx].ds_done {
                    continue;
                }
                let down_ok = r + 1 >= rows || pes[idx + cols].w_fifo.has_space();
                let right_ok = c + 1 >= cols || pes[idx + 1].f_fifo.has_space();
                let out = pes[idx].ds_step(down_ok, right_ok, &mut stats);
                if let Some(t) = out.fwd.w {
                    if r + 1 < rows {
                        pes[idx + cols].w_fifo.push(t);
                        stats.token_pushes += 1;
                    }
                }
                if let Some(t) = out.fwd.f {
                    if c + 1 < cols {
                        pes[idx + 1].f_fifo.push(t);
                        stats.token_pushes += 1;
                    }
                }
            }
        }

        // 3. MAC tick every `ratio` DS cycles.
        mac_countdown -= 1;
        if mac_countdown == 0 {
            mac_countdown = ratio;
            for pe in pes.iter_mut() {
                let was_done = pe.compute_done;
                pe.mac_step(ds_cycle, &mut stats);
                if pe.compute_done && !was_done {
                    remaining -= 1;
                }
            }
        }

        ds_cycle += 1;
        if ds_cycle > CYCLE_LIMIT {
            panic!(
                "tile simulation exceeded {CYCLE_LIMIT} DS cycles \
                 ({remaining} PEs unfinished) — dataflow deadlock"
            );
        }
    }

    // 4. Result forwarding: each column drains its R results in row
    //    order, one per MAC cycle; a PE that finished early stalls its RF
    //    until its predecessors' results have passed (Section 4.1).
    let mut max_drain_mac: u64 = 0;
    for c in 0..cols {
        let mut t: u64 = 0;
        for r in 0..rows {
            let fin_mac = pes[r * cols + c].finish_ds_cycle / ratio + 1;
            t = (t + 1).max(fin_mac + 1);
        }
        max_drain_mac = max_drain_mac.max(t);
    }
    stats.ds_cycles = ds_cycle.max(max_drain_mac * ratio);

    // 5. Buffer traffic accounting (CE array model).
    let traffic = ce::account(tile, ce_enabled);
    ce::apply(&mut stats, &traffic);

    stats
}
