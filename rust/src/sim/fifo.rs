//! Bounded token FIFOs — the registers inside each PE's DS component
//! (Fig. 6: W-FIFO, F-FIFO, WF-FIFO).
//!
//! The paper sizes these in the few-entries range ("several tens of bits
//! are enough"); their depth is a first-order performance knob (Fig. 10),
//! so the simulator models occupancy exactly.
//!
//! Perf note (EXPERIMENTS.md §Perf): the common configurations are depth
//! ≤ 8, so the ring lives in an inline array inside the PE struct — no
//! heap indirection on the simulator hot path. Deeper / idealized (∞)
//! FIFOs spill to a heap ring.

const INLINE_CAP: usize = 8;

/// Ring-buffer FIFO of packed tokens (`u32`). Capacity `usize::MAX`
/// models the paper's idealized (∞,∞,∞) configuration.
#[derive(Debug, Clone)]
pub struct Fifo {
    inline: [u32; INLINE_CAP],
    heap: Vec<u32>,
    head: u32,
    len: u32,
    cap: usize,
    /// Lifetime statistics.
    pub pushes: u64,
    pub max_occupancy: usize,
}

impl Fifo {
    pub fn new(cap: usize) -> Self {
        let heap = if cap > INLINE_CAP {
            let alloc = if cap == usize::MAX { 64 } else { cap };
            vec![0; alloc]
        } else {
            Vec::new()
        };
        Fifo {
            inline: [0; INLINE_CAP],
            heap,
            head: 0,
            len: 0,
            cap: cap.max(1),
            pushes: 0,
            max_occupancy: 0,
        }
    }

    /// Reinitialize in place for a (possibly different) capacity, keeping
    /// the heap ring allocation when it is already large enough — the
    /// SimScratch reuse path, so repeated tile simulations allocate
    /// nothing per tile.
    pub fn reset(&mut self, cap: usize) {
        self.cap = cap.max(1);
        self.head = 0;
        self.len = 0;
        self.pushes = 0;
        self.max_occupancy = 0;
        if cap > INLINE_CAP {
            let need = if cap == usize::MAX {
                // idealized FIFO: keep whatever the ring grew to
                self.heap.len().max(64)
            } else {
                // bounded ring arithmetic only needs len >= cap; a larger
                // leftover ring from a previous (deeper/∞) config is fine
                self.heap.len().max(cap)
            };
            if self.heap.len() < need {
                self.heap.resize(need, 0);
            }
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.cap != usize::MAX && self.len as usize >= self.cap
    }

    #[inline]
    pub fn has_space(&self) -> bool {
        !self.is_full()
    }

    #[inline]
    fn ring_len(&self) -> usize {
        if self.cap <= INLINE_CAP {
            INLINE_CAP
        } else {
            self.heap.len()
        }
    }

    /// Push a token; panics if full (callers must check `has_space` —
    /// backpressure is the caller's concern, mirroring the RTL valid/ready
    /// handshake).
    #[inline]
    pub fn push(&mut self, v: u32) {
        let ring = self.ring_len();
        if self.len as usize == ring {
            debug_assert_eq!(self.cap, usize::MAX, "push into full bounded FIFO");
            self.grow();
        }
        if self.cap <= INLINE_CAP {
            // inline ring is always 8 slots: mask instead of modulo
            let tail = (self.head as usize + self.len as usize) & (INLINE_CAP - 1);
            self.inline[tail] = v;
        } else {
            let tail =
                (self.head as usize + self.len as usize) % self.heap.len();
            self.heap[tail] = v;
        }
        self.len += 1;
        self.pushes += 1;
        if self.len as usize > self.max_occupancy {
            self.max_occupancy = self.len as usize;
        }
    }

    #[cold]
    fn grow(&mut self) {
        let old = self.heap.len();
        let mut nb = vec![0; (old * 2).max(64)];
        for i in 0..self.len as usize {
            nb[i] = self.heap[(self.head as usize + i) % old];
        }
        self.heap = nb;
        self.head = 0;
    }

    #[inline]
    pub fn pop(&mut self) -> Option<u32> {
        if self.len == 0 {
            return None;
        }
        let v = if self.cap <= INLINE_CAP {
            let v = self.inline[self.head as usize];
            self.head = ((self.head as usize + 1) & (INLINE_CAP - 1)) as u32;
            v
        } else {
            let v = self.heap[self.head as usize];
            self.head = ((self.head as usize + 1) % self.heap.len()) as u32;
            v
        };
        self.len -= 1;
        Some(v)
    }

    #[inline]
    pub fn peek(&self) -> Option<u32> {
        if self.len == 0 {
            None
        } else if self.cap <= INLINE_CAP {
            Some(self.inline[self.head as usize])
        } else {
            Some(self.heap[self.head as usize])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_bounds() {
        let mut f = Fifo::new(3);
        assert!(f.is_empty());
        f.push(1);
        f.push(2);
        f.push(3);
        assert!(f.is_full());
        assert!(!f.has_space());
        assert_eq!(f.pop(), Some(1));
        f.push(4);
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn wraparound_many_times() {
        let mut f = Fifo::new(2);
        for i in 0..100u32 {
            f.push(i);
            assert_eq!(f.pop(), Some(i));
        }
        assert_eq!(f.pushes, 100);
        assert_eq!(f.max_occupancy, 1);
    }

    #[test]
    fn heap_backed_depths() {
        // cap > INLINE_CAP uses the heap ring with identical semantics
        let mut f = Fifo::new(16);
        for i in 0..16u32 {
            assert!(f.has_space());
            f.push(i);
        }
        assert!(f.is_full());
        for i in 0..16u32 {
            assert_eq!(f.pop(), Some(i));
        }
    }

    #[test]
    fn infinite_fifo_grows() {
        let mut f = Fifo::new(usize::MAX);
        for i in 0..1000u32 {
            assert!(f.has_space());
            f.push(i);
        }
        for i in 0..1000u32 {
            assert_eq!(f.pop(), Some(i));
        }
    }

    #[test]
    fn infinite_ring_grows_past_64_with_wrapped_head() {
        // Regression (ISSUE 1 audit): the idealized (∞) FIFO pre-allocates
        // only 64 heap slots; growth must preserve FIFO order and
        // max_occupancy even when the ring head has wrapped mid-buffer.
        let mut f = Fifo::new(usize::MAX);
        for i in 0..64u32 {
            f.push(i);
        }
        for i in 0..30u32 {
            assert_eq!(f.pop(), Some(i)); // head now at slot 30
        }
        // refill past the 64-slot ring: forces grow() with head != 0
        for i in 64..200u32 {
            assert!(f.has_space());
            f.push(i);
        }
        assert_eq!(f.len(), 170);
        assert_eq!(f.max_occupancy, 170);
        for i in 30..200u32 {
            assert_eq!(f.pop(), Some(i), "order broken at {i}");
        }
        assert_eq!(f.pop(), None);
        assert_eq!(f.pushes, 200);
    }

    #[test]
    fn infinite_ring_multiple_growth_rounds() {
        let mut f = Fifo::new(usize::MAX);
        // 64 -> 128 -> 256 -> 512: three grow() calls, interleaved pops
        for i in 0..400u32 {
            f.push(i);
            if i % 3 == 0 {
                let expect = (i / 3) as u32;
                assert_eq!(f.pop(), Some(expect));
            }
        }
        let mut expect = 134u32; // 401 pushes? no: 400 pushes, 134 pops
        while let Some(v) = f.pop() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, 400);
    }

    #[test]
    fn reset_reuses_ring_and_clears_stats() {
        let mut f = Fifo::new(usize::MAX);
        for i in 0..100u32 {
            f.push(i);
        }
        f.reset(usize::MAX);
        assert!(f.is_empty());
        assert_eq!(f.pushes, 0);
        assert_eq!(f.max_occupancy, 0);
        for i in 0..100u32 {
            f.push(i);
        }
        for i in 0..100u32 {
            assert_eq!(f.pop(), Some(i));
        }
        // reset to a bounded depth: bounds enforced again
        f.reset(3);
        f.push(1);
        f.push(2);
        f.push(3);
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(1));
        // and back down to an inline depth
        f.reset(2);
        assert!(f.is_empty());
        f.push(7);
        f.push(8);
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(7));
        assert_eq!(f.pop(), Some(8));
    }

    #[test]
    fn peek_does_not_consume() {
        let mut f = Fifo::new(4);
        f.push(9);
        assert_eq!(f.peek(), Some(9));
        assert_eq!(f.len(), 1);
        assert_eq!(f.pop(), Some(9));
        assert_eq!(f.peek(), None);
    }

    #[test]
    #[should_panic]
    fn bounded_overflow_panics_in_debug() {
        let mut f = Fifo::new(1);
        f.push(1);
        f.push(2); // must panic (debug_assert) or corrupt — test debug only
        // in release the debug_assert is compiled out; force failure:
        assert!(f.len() <= 1, "overflow silently accepted");
    }
}
