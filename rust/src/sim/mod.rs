//! Cycle-accurate simulator of the S²Engine array.
//!
//! * [`fifo`] — the bounded token FIFOs inside each PE.
//! * [`pe`] — Dynamic Selection + MAC + Result Forwarding state machines.
//! * [`array`] — the R×C array at DS-clock granularity (event-driven
//!   active-PE scheduler, EXPERIMENTS.md §Perf).
//! * [`reference`] — the original full-sweep engine, retained as the
//!   bit-exactness oracle for the event-driven one.
//! * [`scratch`] — reusable flat-arena workspace threaded through the
//!   coordinator's worker pool.
//! * [`ce`] — Collective Element buffer-traffic accounting.
//! * [`buffer`] — FB/WB SRAM capacity provisioning (Section 5.2's
//!   66-of-71 / 68-of-71 layer-fit analysis).
//! * [`stats`] — event counters feeding the energy/area models.

pub mod array;
pub mod buffer;
pub mod ce;
pub mod fifo;
pub mod pe;
pub mod reference;
pub mod scratch;
pub mod stats;

pub use array::{simulate_tile, simulate_tile_with_scratch};
pub use reference::simulate_tile_reference;
pub use scratch::SimScratch;
pub use stats::TileStats;
