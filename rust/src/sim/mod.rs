//! Cycle-accurate simulator of the S²Engine array.
//!
//! * [`fifo`] — the bounded token FIFOs inside each PE.
//! * [`pe`] — Dynamic Selection + MAC + Result Forwarding state machines.
//! * [`array`] — the R×C array stepped at DS-clock granularity.
//! * [`ce`] — Collective Element buffer-traffic accounting.
//! * [`buffer`] — FB/WB SRAM capacity provisioning (Section 5.2's
//!   66-of-71 / 68-of-71 layer-fit analysis).
//! * [`stats`] — event counters feeding the energy/area models.

pub mod array;
pub mod buffer;
pub mod ce;
pub mod fifo;
pub mod pe;
pub mod stats;

pub use array::simulate_tile;
pub use stats::TileStats;
