//! The processing element: Dynamic Selection + MAC + Result Forwarding
//! (Section 4.3, Figs. 6/7).
//!
//! DS semantics implemented exactly as the paper's toy trace (Fig. 7):
//! a *push* of a flow moves one token from the flow FIFO into the
//! comparison register **and simultaneously forwards it to the successor
//! PE** on that flow's transmission path. Each DS cycle the controller
//! compares the two register offsets:
//!
//! * equal offsets, both non-zero → the aligned pair enters the WF-FIFO
//!   and (normally) both flows push;
//! * unequal → the flow with the smaller offset pushes (it can no longer
//!   find a partner in the other flow's remaining, offset-sorted group);
//! * a flow whose register carries EOG holds until the other reaches its
//!   EOG too, then both push together — the group barrier that keeps the
//!   two compressed flows group-synchronized.
//!
//! Any required push that cannot proceed (empty source FIFO, full
//! downstream FIFO, full WF-FIFO) stalls the whole DS cycle — emission
//! and pushes are atomic, as in the RTL handshake.
//!
//! Split 16-bit values (Section 4.5) are pairs of same-offset tokens
//! (lo then hi). On an offset match where one register holds a `lo`
//! token, only that flow pushes, so the partner is re-paired with the
//! following `hi` token; a hi×hi match books 2 MAC ops, totalling the 4
//! partial products of Fig. 9(b) for a 16×16 encounter.

use super::fifo::Fifo;
use super::stats::TileStats;
use crate::compiler::ecoo::Token;
use crate::config::FifoDepths;

const EMPTY: u32 = 0;

/// What a DS cycle decided to forward to the neighbours.
#[derive(Debug, Default, Clone, Copy)]
pub struct Forwarded {
    /// Token to hand to the next PE down the column (weight flow).
    pub w: Option<u32>,
    /// Token to hand to the next PE right along the row (feature flow).
    pub f: Option<u32>,
}

/// Which stall counter a DS cycle bumped (at most one per cycle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stall {
    None,
    /// `stall_starved` — waiting on an input token (or, during register
    /// fill, on downstream space for the fill's forward).
    Starved,
    /// `stall_out_full` — a required push found the successor FIFO full.
    OutFull,
    /// `stall_wf_full` — an aligned pair found the WF-FIFO full.
    WfFull,
}

/// Wake-need bits for a stalled step: which resource event could change
/// this PE's decision. The event scheduler only re-steps a parked PE on a
/// matching event; any *other* event provably reproduces the same stall
/// (the paper semantics make the blocking resource unambiguous), which is
/// what keeps parked accrual bit-identical to the sweep.
pub mod need {
    /// A token arriving in the PE's own W-FIFO.
    pub const W_TOKEN: u8 = 1;
    /// Space freed in the downstream PE's W-FIFO.
    pub const W_SPACE: u8 = 2;
    /// A token arriving in the PE's own F-FIFO.
    pub const F_TOKEN: u8 = 4;
    /// Space freed in the right-hand PE's F-FIFO.
    pub const F_SPACE: u8 = 8;
    /// Space freed in the PE's own WF-FIFO (MAC tick pop).
    pub const WF_SPACE: u8 = 16;
}

/// Full result of one DS-clock step, consumed by the event scheduler
/// ([`super::array`]): `fwd` carries inter-PE token movement, `progressed`
/// says whether any architectural state changed (register fill, pair
/// emission, barrier, ds_done), `stall` names the counter bumped, and
/// `need` the wake events that could unblock a stalled step. A register
/// fill can both progress *and* stall (one flow filled, the other
/// missing), so `progressed` and `stall` are independent.
#[derive(Debug, Clone, Copy)]
pub struct StepOutcome {
    pub fwd: Forwarded,
    pub progressed: bool,
    pub stall: Stall,
    /// OR of [`need`] bits; 0 unless `stall != Stall::None`.
    pub need: u8,
}

impl StepOutcome {
    #[inline]
    fn stalled(stall: Stall, need: u8) -> Self {
        StepOutcome {
            fwd: Forwarded::default(),
            progressed: false,
            stall,
            need,
        }
    }
}

/// MAC-side state: the WF-FIFO holds emitted pairs as op-counts.
#[derive(Debug, Clone)]
pub struct Pe {
    pub w_fifo: Fifo,
    pub f_fifo: Fifo,
    /// WF-FIFO: each entry is the op-count of one aligned pair (1 or 2).
    pub wf_fifo: Fifo,
    w_reg: u32,
    f_reg: u32,
    /// Completed group barriers.
    pub groups_done: u32,
    /// Total groups this PE must process (its convolution's length).
    pub n_groups: u32,
    /// DS has consumed all groups.
    pub ds_done: bool,
    /// MAC has drained the WF-FIFO after ds_done.
    pub compute_done: bool,
    /// MAC ops performed by this PE.
    pub mac_ops: u64,
    /// DS cycle at which compute finished (valid once compute_done).
    pub finish_ds_cycle: u64,
    /// True if this PE is inactive in the current tile (edge padding).
    pub idle: bool,
}

impl Pe {
    pub fn new(depths: FifoDepths, n_groups: u32) -> Self {
        Pe {
            w_fifo: Fifo::new(depths.w),
            f_fifo: Fifo::new(depths.f),
            wf_fifo: Fifo::new(depths.wf),
            w_reg: EMPTY,
            f_reg: EMPTY,
            groups_done: 0,
            n_groups,
            ds_done: n_groups == 0,
            compute_done: n_groups == 0,
            mac_ops: 0,
            finish_ds_cycle: 0,
            idle: n_groups == 0,
        }
    }

    /// Both comparison registers empty (cheap pre-check for certain
    /// starvation in the array sweep).
    #[inline]
    pub fn regs_empty(&self) -> bool {
        self.w_reg == EMPTY && self.f_reg == EMPTY
    }

    /// Reinitialize in place to the `Pe::new` state, keeping any heap
    /// allocations inside the FIFOs (SimScratch reuse across tiles).
    pub fn reset(&mut self, depths: FifoDepths, n_groups: u32) {
        self.w_fifo.reset(depths.w);
        self.f_fifo.reset(depths.f);
        self.wf_fifo.reset(depths.wf);
        self.w_reg = EMPTY;
        self.f_reg = EMPTY;
        self.groups_done = 0;
        self.n_groups = n_groups;
        self.ds_done = n_groups == 0;
        self.compute_done = n_groups == 0;
        self.mac_ops = 0;
        self.finish_ds_cycle = 0;
        self.idle = n_groups == 0;
    }

    /// One DS-clock step. `w_space_down` / `f_space_right` report whether
    /// the successor FIFOs can accept a token (`true` at array edges).
    pub fn ds_step(
        &mut self,
        w_space_down: bool,
        f_space_right: bool,
        stats: &mut TileStats,
    ) -> StepOutcome {
        if self.ds_done {
            return StepOutcome::stalled(Stall::None, 0);
        }

        // Register fills are pushes too: they forward the loaded token,
        // and a flow can push at most once per DS cycle — so a fill
        // consumes the cycle (the compare resumes next cycle), exactly
        // one token per flow per cycle on the transmission path. The two
        // flows fill independently: a starved weight side must not block
        // feature tokens from propagating (and vice versa). Fills only
        // happen at stream start, so this path is cold.
        if self.w_reg == EMPTY || self.f_reg == EMPTY {
            return self.fill_regs(w_space_down, f_space_right, stats);
        }

        let mut fwd = Forwarded::default();
        let w = Token(self.w_reg);
        let f = Token(self.f_reg);
        let w_last = w.eog();
        let f_last = f.eog();
        let aligned =
            w.offset() == f.offset() && !w.is_placeholder() && !f.is_placeholder();

        // Decide which flows must push this cycle.
        let (push_w, push_f, barrier) = if aligned && f.tag16() && !f.hi() {
            (false, true, false) // hold w for f's hi byte
        } else if aligned && w.tag16() && !w.hi() {
            (true, false, false) // hold f for w's hi byte
        } else if w_last && f_last {
            (true, true, true)
        } else if w_last {
            (false, true, false)
        } else if f_last {
            (true, false, false)
        } else if w.offset() == f.offset() {
            (true, true, false)
        } else if w.offset() < f.offset() {
            (true, false, false)
        } else {
            (false, true, false)
        };

        // Feasibility check before any side effect (atomic cycle).
        if aligned && !self.wf_fifo.has_space() {
            stats.stall_wf_full += 1;
            return StepOutcome::stalled(Stall::WfFull, need::WF_SPACE);
        }
        let final_barrier = barrier && self.groups_done + 1 == self.n_groups;
        if !final_barrier {
            if push_w && (self.w_fifo.is_empty() || !w_space_down) {
                return if self.w_fifo.is_empty() {
                    stats.stall_starved += 1;
                    StepOutcome::stalled(Stall::Starved, need::W_TOKEN)
                } else {
                    stats.stall_out_full += 1;
                    StepOutcome::stalled(Stall::OutFull, need::W_SPACE)
                };
            }
            if push_f && (self.f_fifo.is_empty() || !f_space_right) {
                return if self.f_fifo.is_empty() {
                    stats.stall_starved += 1;
                    StepOutcome::stalled(Stall::Starved, need::F_TOKEN)
                } else {
                    stats.stall_out_full += 1;
                    StepOutcome::stalled(Stall::OutFull, need::F_SPACE)
                };
            }
        }

        // Emit the aligned pair.
        if aligned {
            let ops = if w.tag16() && w.hi() && f.tag16() && f.hi() {
                2 // the hi*hi encounter also covers the lo*hi cross term
            } else {
                1
            };
            self.wf_fifo.push(ops);
            stats.pairs += 1;
            stats.mac_ops += ops as u64;
            self.mac_ops += ops as u64;
        }

        // Perform the pushes.
        if barrier {
            self.groups_done += 1;
            stats.barrier_cycles += 1;
            if final_barrier {
                self.w_reg = EMPTY;
                self.f_reg = EMPTY;
                self.ds_done = true;
                return StepOutcome {
                    fwd,
                    progressed: true,
                    stall: Stall::None,
                    need: 0,
                };
            }
        }
        if push_w {
            let ok = self.try_load_w(&mut fwd, w_space_down);
            debug_assert!(ok, "checked above");
        }
        if push_f {
            let ok = self.try_load_f(&mut fwd, f_space_right);
            debug_assert!(ok, "checked above");
        }
        StepOutcome {
            fwd,
            progressed: true,
            stall: Stall::None,
            need: 0,
        }
    }

    /// Cold path: one or both comparison registers are empty (stream
    /// start). Fill what can be filled, forwarding the loaded tokens.
    #[cold]
    fn fill_regs(
        &mut self,
        w_space_down: bool,
        f_space_right: bool,
        stats: &mut TileStats,
    ) -> StepOutcome {
        let mut fwd = Forwarded::default();
        let mut needs: u8 = 0;
        if self.w_reg == EMPTY && !self.try_load_w(&mut fwd, w_space_down) {
            // blocked on either a W token or downstream W space
            needs |= need::W_TOKEN | need::W_SPACE;
        }
        if self.f_reg == EMPTY && !self.try_load_f(&mut fwd, f_space_right) {
            needs |= need::F_TOKEN | need::F_SPACE;
        }
        if needs != 0 {
            stats.stall_starved += 1;
        }
        StepOutcome {
            progressed: fwd.w.is_some() || fwd.f.is_some(),
            stall: if needs != 0 { Stall::Starved } else { Stall::None },
            need: needs,
            fwd,
        }
    }

    fn try_load_w(&mut self, fwd: &mut Forwarded, space_down: bool) -> bool {
        if self.w_fifo.is_empty() || !space_down {
            return false;
        }
        let t = self.w_fifo.pop().unwrap();
        self.w_reg = t;
        fwd.w = Some(t);
        true
    }

    fn try_load_f(&mut self, fwd: &mut Forwarded, space_right: bool) -> bool {
        if self.f_fifo.is_empty() || !space_right {
            return false;
        }
        let t = self.f_fifo.pop().unwrap();
        self.f_reg = t;
        fwd.f = Some(t);
        true
    }

    /// One MAC-clock step: consume one op from the WF-FIFO head.
    pub fn mac_step(&mut self, ds_cycle: u64, stats: &mut TileStats) {
        if self.compute_done {
            return;
        }
        match self.wf_fifo.peek() {
            Some(ops) => {
                self.wf_fifo.pop();
                if ops > 1 {
                    // multi-op pair: re-queue the remainder (occupies the
                    // head slot for another MAC cycle)
                    // NOTE: pushed at tail; order within a PE's pair
                    // stream is irrelevant to the accumulation result.
                    self.wf_fifo.push(ops - 1);
                }
            }
            None => {
                if self.ds_done {
                    self.compute_done = true;
                    self.finish_ds_cycle = ds_cycle;
                } else {
                    stats.mac_idle += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ecoo::EcooFlow;

    fn pe_with_flows(w_data: &[i8], f_data: &[i8], depths: FifoDepths) -> Pe {
        let wf = EcooFlow::encode_kernel(w_data);
        let ff = EcooFlow::encode(f_data);
        assert_eq!(wf.n_groups, ff.n_groups);
        let mut pe = Pe::new(depths, wf.n_groups as u32);
        for t in &wf.tokens {
            pe.w_fifo.push(t.0);
        }
        for t in &ff.tokens {
            pe.f_fifo.push(t.0);
        }
        pe
    }

    /// Run DS+MAC until done; returns (ds_cycles, mac_ops, pairs).
    fn run(pe: &mut Pe, ratio: u64) -> (u64, u64, u64) {
        let mut stats = TileStats::default();
        let mut cycle = 0u64;
        while !pe.compute_done && cycle < 100_000 {
            pe.ds_step(true, true, &mut stats);
            if cycle % ratio == ratio - 1 {
                pe.mac_step(cycle, &mut stats);
            }
            cycle += 1;
        }
        assert!(pe.compute_done, "PE did not finish");
        (cycle, pe.mac_ops, stats.pairs)
    }

    fn group(nz: &[(usize, i8)]) -> Vec<i8> {
        let mut g = vec![0i8; 16];
        for &(o, v) in nz {
            g[o] = v;
        }
        g
    }

    #[test]
    fn fully_aligned_group() {
        // identical offsets => every nonzero is a must-MAC
        let w = group(&[(1, 5), (4, 2), (9, -3)]);
        let f = group(&[(1, 7), (4, 1), (9, 2)]);
        let mut pe = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (_, mac_ops, pairs) = run(&mut pe, 1);
        assert_eq!(pairs, 3);
        assert_eq!(mac_ops, 3);
    }

    #[test]
    fn disjoint_offsets_no_pairs() {
        let w = group(&[(0, 5), (2, 2)]);
        let f = group(&[(1, 7), (3, 1)]);
        let mut pe = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (_, mac_ops, _) = run(&mut pe, 1);
        assert_eq!(mac_ops, 0);
    }

    #[test]
    fn paper_fig7_trace() {
        // Fig. 5/7 toy: weight group has nonzeros at offsets {1,3},
        // feature at {1,4}: single aligned pair at offset 1.
        let w = group(&[(1, 10), (3, -2)]);
        let f = group(&[(1, 3), (4, 8)]);
        let mut pe = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (cycles, mac_ops, pairs) = run(&mut pe, 4);
        assert_eq!(pairs, 1);
        assert_eq!(mac_ops, 1);
        // the paper's trace resolves this group in ~5 DS cycles
        assert!(cycles <= 8, "took {cycles} cycles");
    }

    #[test]
    fn empty_groups_barrier_only() {
        let w = vec![0i8; 32]; // two all-zero groups
        let f = vec![0i8; 32];
        let mut pe = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (cycles, mac_ops, _) = run(&mut pe, 1);
        assert_eq!(mac_ops, 0);
        assert!(cycles <= 6, "placeholder groups took {cycles}");
    }

    #[test]
    fn multi_group_sync() {
        // group0: w={0}, f={15}; group1: w={3,7}, f={3,7}
        let mut w = group(&[(0, 1)]);
        w.extend(group(&[(3, 2), (7, 4)]));
        let mut f = group(&[(15, 1)]);
        f.extend(group(&[(3, 5), (7, 6)]));
        let mut pe = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (_, mac_ops, _) = run(&mut pe, 1);
        assert_eq!(mac_ops, 2);
        assert_eq!(pe.groups_done, 2);
    }

    #[test]
    fn dense_groups_match_naive_cost() {
        // fully dense groups: every offset aligned => 16 pairs
        let w: Vec<i8> = (1..=16).collect();
        let f: Vec<i8> = (1..=16).map(|v| -v).collect();
        let mut pe = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (_, mac_ops, _) = run(&mut pe, 1);
        assert_eq!(mac_ops, 16);
    }

    #[test]
    fn mixed_precision_16x16_yields_4_ops() {
        use crate::compiler::precision::encode_mixed;
        let mut wd = vec![0i16; 16];
        wd[5] = 1000;
        let mut fd = vec![0i16; 16];
        fd[5] = -2000;
        let wf = encode_mixed(&wd);
        let ff = encode_mixed(&fd);
        let mut pe = Pe::new(FifoDepths::infinite(), 1);
        let mut toks = wf.tokens.clone();
        if let Some(l) = toks.last_mut() {
            *l = l.with_eok();
        }
        for t in &toks {
            pe.w_fifo.push(t.0);
        }
        for t in &ff.tokens {
            pe.f_fifo.push(t.0);
        }
        let (_, mac_ops, _) = run(&mut pe, 1);
        assert_eq!(mac_ops, 4, "16x16 must book 4 partial products");
    }

    #[test]
    fn mixed_precision_16x8_yields_2_ops() {
        use crate::compiler::precision::encode_mixed;
        let mut wd = vec![0i16; 16];
        wd[5] = 1000; // 16-bit
        let mut fd = vec![0i16; 16];
        fd[5] = 100; // 8-bit
        let wf = encode_mixed(&wd);
        let ff = encode_mixed(&fd);
        let mut pe = Pe::new(FifoDepths::infinite(), 1);
        for t in &wf.tokens {
            pe.w_fifo.push(t.0);
        }
        for t in &ff.tokens {
            pe.f_fifo.push(t.0);
        }
        let (_, mac_ops, _) = run(&mut pe, 1);
        assert_eq!(mac_ops, 2);
    }

    #[test]
    fn sparse_group_faster_than_dense() {
        let wd = group(&[(2, 1)]);
        let fd = group(&[(9, 1)]);
        let mut sparse = pe_with_flows(&wd, &fd, FifoDepths::infinite());
        let (sparse_cycles, _, _) = run(&mut sparse, 4);

        let w: Vec<i8> = (1..=16).collect();
        let f: Vec<i8> = (1..=16).collect();
        let mut dense = pe_with_flows(&w, &f, FifoDepths::infinite());
        let (dense_cycles, _, _) = run(&mut dense, 4);
        assert!(
            sparse_cycles * 3 < dense_cycles,
            "sparse {sparse_cycles} vs dense {dense_cycles}"
        );
    }

    #[test]
    fn forwards_every_token_exactly_once() {
        let w = group(&[(1, 5), (4, 2), (9, -3)]);
        let f = group(&[(0, 7), (4, 1), (11, 2)]);
        let wf = EcooFlow::encode_kernel(&w);
        let ff = EcooFlow::encode(&f);
        let mut pe = Pe::new(FifoDepths::infinite(), 1);
        for t in &wf.tokens {
            pe.w_fifo.push(t.0);
        }
        for t in &ff.tokens {
            pe.f_fifo.push(t.0);
        }
        let mut stats = TileStats::default();
        let mut got_w = Vec::new();
        let mut got_f = Vec::new();
        for cycle in 0..1000 {
            let out = pe.ds_step(true, true, &mut stats);
            if let Some(t) = out.fwd.w {
                got_w.push(t);
            }
            if let Some(t) = out.fwd.f {
                got_f.push(t);
            }
            pe.mac_step(cycle, &mut stats);
            if pe.compute_done {
                break;
            }
        }
        let want_w: Vec<u32> = wf.tokens.iter().map(|t| t.0).collect();
        let want_f: Vec<u32> = ff.tokens.iter().map(|t| t.0).collect();
        assert_eq!(got_w, want_w, "weight flow must pass through verbatim");
        assert_eq!(got_f, want_f, "feature flow must pass through verbatim");
    }

    #[test]
    fn bounded_wf_fifo_backpressures_ds() {
        // tiny WF-FIFO and slow MAC: DS must stall on wf_full
        let w: Vec<i8> = (1..=16).collect();
        let f: Vec<i8> = (1..=16).collect();
        let mut pe = pe_with_flows(&w, &f, FifoDepths::new(16, 16, 1));
        let mut stats = TileStats::default();
        let mut cycle = 0u64;
        while !pe.compute_done && cycle < 10_000 {
            pe.ds_step(true, true, &mut stats);
            if cycle % 8 == 7 {
                pe.mac_step(cycle, &mut stats);
            }
            cycle += 1;
        }
        assert!(pe.compute_done);
        assert!(stats.stall_wf_full > 0, "expected WF-full stalls");
        assert_eq!(pe.mac_ops, 16);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let w = group(&[(1, 5)]);
        let f = group(&[(1, 7)]);
        let mut pe = pe_with_flows(&w, &f, FifoDepths::uniform(4));
        let _ = run(&mut pe, 1);
        assert!(pe.compute_done);
        pe.reset(FifoDepths::uniform(4), 3);
        assert!(!pe.ds_done && !pe.compute_done);
        assert_eq!(pe.mac_ops, 0);
        assert_eq!(pe.groups_done, 0);
        assert_eq!(pe.n_groups, 3);
        assert!(pe.w_fifo.is_empty());
        assert!(pe.f_fifo.is_empty());
        assert!(pe.wf_fifo.is_empty());
    }
}
