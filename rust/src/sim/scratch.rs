//! Reusable per-worker simulation workspace (EXPERIMENTS.md §Perf).
//!
//! `simulate_tile` used to allocate per tile: one `Vec<Vec<u32>>` per
//! flow side (a heap allocation per PE row/column), a fresh `Vec<Pe>`,
//! and — for deep/idealized FIFOs — a heap ring per FIFO. Under the
//! coordinator's worker pool that multiplied into thousands of
//! allocations per layer. `SimScratch` owns all of that state as flat
//! arenas (one token buffer + `(start, end)` ranges instead of nested
//! vectors; SoA scheduler arrays alongside the PE structs) and is reused
//! across tiles: the coordinator threads one instance per worker via
//! [`crate::util::pool::par_map_with`], and direct `simulate_tile` calls
//! fall back to a thread-local instance.

use super::pe::Pe;

/// Park-category encoding for the event scheduler's SoA state
/// (mirrors [`super::pe::Stall`]; 0 = not parked).
pub(crate) const PARK_NONE: u8 = 0;
pub(crate) const PARK_STARVED: u8 = 1;
pub(crate) const PARK_OUT_FULL: u8 = 2;
pub(crate) const PARK_WF_FULL: u8 = 3;

/// Flat, reusable buffers for one in-flight tile simulation.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// Token arena: every row's feature flow followed by every column's
    /// weight flow, addressed by the `(start, end)` ranges below.
    pub(crate) tokens: Vec<u32>,
    pub(crate) f_range: Vec<(u32, u32)>,
    pub(crate) w_range: Vec<(u32, u32)>,
    /// Next-token cursor per row/column (absolute index into `tokens`).
    pub(crate) f_idx: Vec<u32>,
    pub(crate) w_idx: Vec<u32>,
    /// Rows/columns whose source stream is not yet exhausted.
    pub(crate) live_rows: Vec<u32>,
    pub(crate) live_cols: Vec<u32>,

    /// PE state, reused across tiles via [`Pe::reset`].
    pub(crate) pes: Vec<Pe>,

    // --- event-scheduler state (SoA over PE index) ---
    /// Worklist bitset for the current DS cycle: the scan drains the
    /// highest set bit first, reproducing the reference's reverse raster
    /// order; set-bit = O(1) dedup'd wake. Same-cycle wakes always target
    /// indices below the scan position, so they are picked up in order.
    pub(crate) cur: Vec<u64>,
    /// Worklist bitset for the next DS cycle.
    pub(crate) nxt: Vec<u64>,
    /// PARK_* category of each stalled PE (0 = active or DS-done).
    pub(crate) park_cat: Vec<u8>,
    /// Wake-need mask ([`super::pe::need`]) of each parked PE: only a
    /// matching resource event re-steps it.
    pub(crate) park_need: Vec<u8>,
    /// Bit 0: PE is in the first column, bit 1: last column — precomputed
    /// so the DS hot loop needs no div/mod for neighbour lookups.
    pub(crate) edge_flags: Vec<u8>,

    // --- MAC-side state ---
    /// PEs with a non-empty WF-FIFO (popped once per MAC tick).
    pub(crate) wf_busy: Vec<u32>,
    /// PEs that are DS-done with a drained WF-FIFO: they complete at the
    /// next MAC tick.
    pub(crate) finishing: Vec<u32>,
}

impl SimScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset every buffer for a tile of `n` PEs with `rows`×`cols`
    /// geometry, keeping allocations.
    pub(crate) fn reset_for(&mut self, rows: usize, cols: usize) {
        let n = rows * cols;
        self.tokens.clear();
        self.f_range.clear();
        self.w_range.clear();
        self.f_idx.clear();
        self.w_idx.clear();
        self.live_rows.clear();
        self.live_cols.clear();
        let words = n.div_ceil(64);
        self.cur.clear();
        self.cur.resize(words, 0);
        self.nxt.clear();
        self.nxt.resize(words, 0);
        self.park_cat.clear();
        self.park_cat.resize(n, PARK_NONE);
        self.park_need.clear();
        self.park_need.resize(n, 0);
        self.edge_flags.clear();
        self.edge_flags.reserve(n);
        let mut cc = 0usize;
        for _ in 0..n {
            let mut fl = 0u8;
            if cc == 0 {
                fl |= 1;
            }
            if cc + 1 == cols {
                fl |= 2;
            }
            self.edge_flags.push(fl);
            cc += 1;
            if cc == cols {
                cc = 0;
            }
        }
        self.wf_busy.clear();
        self.finishing.clear();
        self.live_rows.extend(0..rows as u32);
        self.live_cols.extend(0..cols as u32);
    }
}
