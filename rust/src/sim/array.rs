//! The systolic-array tile simulator — event-driven engine.
//!
//! Semantics are the paper's (Section 4.1/4.3): R×C PEs at the DS clock,
//! weight flows travelling down columns, feature flows travelling right
//! along rows, MAC units ticking every `ds_ratio` cycles, and in-order
//! result forwarding per column. One call simulates one *tile* (one array
//! pass over R output positions × C kernels); layer totals are
//! extrapolated by the coordinator from a tile sample (DESIGN.md §5).
//!
//! ## Scheduling (EXPERIMENTS.md §Perf)
//!
//! The original engine (retained in [`super::reference`] as the oracle)
//! swept all R×C PEs every DS cycle even though many of them are provably
//! stalled on any given cycle. This engine steps a PE only when an event
//! could change its decision. Each stalled ("parked") PE records the
//! *need* that blocks it ([`super::pe::need`]) and only a matching
//! resource event re-steps it:
//!
//! * a token arriving in an input FIFO it is starved on — *next* cycle
//!   for in-array pushes (reverse-raster visibility: the upstream PE
//!   steps later in the same cycle, so its push was never visible until
//!   the next one), *this* cycle for source injection;
//! * space freed in the downstream FIFO it is blocked pushing into —
//!   *this* cycle (downstream PEs step earlier in reverse raster order);
//! * a MAC tick popping its WF-FIFO while it is blocked on WF space;
//! * its own previous step made progress (it stays on the worklist).
//!
//! The worklist is a bitset drained highest-index-first, reproducing the
//! reference's reverse raster order exactly while making wakes O(1) and
//! duplicate-free, and parked/finished PEs completely free to skip
//! (whole-word skips). Parked PEs accrue their per-cycle stall counters
//! in O(1) via per-category population counts, so [`TileStats`] stay
//! bit-identical to the reference — enforced by
//! `tests/sim_equivalence.rs`. When the DS frontier is globally stalled
//! the engine skips straight to the next MAC tick, batching the idle
//! cycles' stall accounting.
//!
//! All per-tile state lives in a reusable [`SimScratch`] arena (flat token
//! buffer + SoA scheduler arrays): zero steady-state allocation per tile.

use std::cell::RefCell;

use super::ce;
use super::pe::{need, Pe, Stall};
use super::reference::CYCLE_LIMIT;
use super::scratch::{
    SimScratch, PARK_NONE, PARK_OUT_FULL, PARK_STARVED, PARK_WF_FULL,
};
use super::stats::TileStats;
use crate::compiler::ecoo::Token;
use crate::compiler::mapping::TileJob;
use crate::config::ArrayConfig;

thread_local! {
    /// Fallback workspace for direct `simulate_tile` calls (benches, CLI
    /// replay, tests). The coordinator threads explicit per-worker
    /// scratches instead.
    static SCRATCH: RefCell<SimScratch> = RefCell::new(SimScratch::new());
}

/// Simulate one tile; returns its event counters.
pub fn simulate_tile(tile: &TileJob, cfg: &ArrayConfig, ce_enabled: bool) -> TileStats {
    SCRATCH.with(|s| {
        simulate_tile_with_scratch(tile, cfg, ce_enabled, &mut s.borrow_mut())
    })
}

/// Wake PE `j` (set its worklist bit) if the event `ev` can change its
/// decision: always for active PEs, by need-mask for parked ones. An
/// event that does not match a parked PE's need provably reproduces the
/// identical stall, which the parked accrual already accounts for.
#[inline]
fn wake(bits: &mut [u64], park_cat: &[u8], park_need: &[u8], j: usize, ev: u8) {
    if park_cat[j] != PARK_NONE && park_need[j] & ev == 0 {
        return;
    }
    bits[j >> 6] |= 1u64 << (j & 63);
}

/// Shared diagnostic for the cycle-limit / no-event-source aborts (the
/// reference engine spins to the limit and dies with the same message).
#[cold]
#[inline(never)]
fn deadlock_panic(remaining: usize) -> ! {
    panic!(
        "tile simulation exceeded {CYCLE_LIMIT} DS cycles \
         ({remaining} PEs unfinished) — dataflow deadlock"
    );
}

/// Event-driven tile simulation against a caller-owned workspace.
pub fn simulate_tile_with_scratch(
    tile: &TileJob,
    cfg: &ArrayConfig,
    ce_enabled: bool,
    scratch: &mut SimScratch,
) -> TileStats {
    let rows = tile.active_rows();
    let cols = tile.active_cols();
    assert!(rows > 0 && cols > 0, "empty tile");
    assert!(
        rows <= cfg.rows && cols <= cfg.cols,
        "tile {}x{} exceeds array {}x{}",
        rows,
        cols,
        cfg.rows,
        cfg.cols
    );
    let ratio = cfg.ds_ratio.max(1) as u64;
    let n_groups = tile.n_groups as u32;
    let n = rows * cols;

    let mut stats = TileStats::default();
    stats.dense_macs = tile.dense_macs();
    stats.results = n as u64;

    scratch.reset_for(rows, cols);

    // --- flatten the streams into the token arena (EOK on weight kernels)
    for s in &tile.features {
        let start = scratch.tokens.len() as u32;
        for g in &s.groups {
            for t in &g.tokens {
                scratch.tokens.push(t.0);
            }
        }
        scratch.f_range.push((start, scratch.tokens.len() as u32));
        scratch.f_idx.push(start);
    }
    for s in &tile.weights {
        let start = scratch.tokens.len() as u32;
        for g in &s.groups {
            for t in &g.tokens {
                scratch.tokens.push(t.0);
            }
        }
        let end = scratch.tokens.len() as u32;
        if end > start {
            let last = (end - 1) as usize;
            scratch.tokens[last] = Token(scratch.tokens[last]).with_eok().0;
        }
        scratch.w_range.push((start, end));
        scratch.w_idx.push(start);
    }

    // --- PE state, reused across tiles
    let have = scratch.pes.len().min(n);
    for pe in scratch.pes[..have].iter_mut() {
        pe.reset(cfg.fifo, n_groups);
    }
    while scratch.pes.len() < n {
        scratch.pes.push(Pe::new(cfg.fifo, n_groups));
    }

    let SimScratch {
        tokens,
        f_range,
        w_range,
        f_idx,
        w_idx,
        live_rows,
        live_cols,
        pes,
        cur,
        nxt,
        park_cat,
        park_need,
        edge_flags,
        wf_busy,
        finishing,
    } = scratch;

    // Parked-population counts per PARK_* category: stalled PEs accrue
    // their per-cycle counters through these instead of being stepped.
    let mut counts: [u64; 4] = [0; 4];
    // Parks that happened *this* cycle (the PE's own ds_step already
    // bumped the counter for this cycle; accrual starts next cycle).
    let mut fresh: [u64; 4] = [0; 4];
    let mut n_mac_idle: u64 = n as u64;
    let mut remaining = n;
    let mut ds_cycle: u64 = 0;
    // Decrementing tick counter instead of `ds_cycle % ratio` (ISSUE 1).
    let mut mac_countdown = ratio;

    // Cycle 0: every PE steps (register-fill cold start), as in the sweep.
    for i in 0..n {
        cur[i >> 6] |= 1u64 << (i & 63);
    }

    while remaining > 0 {
        // 1. Source injection: one token per DS cycle per edge PE.
        let mut ri = 0;
        while ri < live_rows.len() {
            let r = live_rows[ri] as usize;
            let edge = r * cols;
            if pes[edge].f_fifo.has_space() {
                pes[edge].f_fifo.push(tokens[f_idx[r] as usize]);
                f_idx[r] += 1;
                stats.f_tokens += 1;
                wake(cur, park_cat, park_need, edge, need::F_TOKEN);
                if f_idx[r] == f_range[r].1 {
                    live_rows.swap_remove(ri);
                    continue;
                }
            }
            ri += 1;
        }
        let mut ci = 0;
        while ci < live_cols.len() {
            let c = live_cols[ci] as usize;
            if pes[c].w_fifo.has_space() {
                pes[c].w_fifo.push(tokens[w_idx[c] as usize]);
                w_idx[c] += 1;
                stats.w_tokens += 1;
                wake(cur, park_cat, park_need, c, need::W_TOKEN);
                if w_idx[c] == w_range[c].1 {
                    live_cols.swap_remove(ci);
                    continue;
                }
            }
            ci += 1;
        }

        // 2. DS phase: drain the worklist bitset from the highest set bit
        //    down — the reference's reverse raster order over the PEs
        //    that step this cycle. Same-cycle wakes only ever set bits
        //    below the scan position, so the live re-read of each word
        //    picks them up in order.
        let mut wi = cur.len();
        while wi > 0 {
            wi -= 1;
            while cur[wi] != 0 {
                let b = 63 - cur[wi].leading_zeros() as usize;
                cur[wi] &= !(1u64 << b);
                let i = (wi << 6) + b;
                // Unpark on activation: the PE steps this cycle, so its
                // counter comes from ds_step, not the parked accrual.
                let cat = park_cat[i] as usize;
                if cat != PARK_NONE as usize {
                    counts[cat] -= 1;
                    park_cat[i] = PARK_NONE;
                }
                if pes[i].ds_done {
                    continue;
                }
                let first_col = edge_flags[i] & 1 != 0;
                let last_col = edge_flags[i] & 2 != 0;
                let down_ok = i + cols >= n || pes[i + cols].w_fifo.has_space();
                let right_ok = last_col || pes[i + 1].f_fifo.has_space();
                let wf_was_empty = pes[i].wf_fifo.is_empty();
                let out = pes[i].ds_step(down_ok, right_ok, &mut stats);

                if let Some(tk) = out.fwd.w {
                    // i popped its W-FIFO: upstream may push this cycle.
                    if i >= cols {
                        wake(cur, park_cat, park_need, i - cols, need::W_SPACE);
                    }
                    if i + cols < n {
                        pes[i + cols].w_fifo.push(tk);
                        stats.token_pushes += 1;
                        wake(nxt, park_cat, park_need, i + cols, need::W_TOKEN);
                    }
                }
                if let Some(tk) = out.fwd.f {
                    if !first_col {
                        wake(cur, park_cat, park_need, i - 1, need::F_SPACE);
                    }
                    if !last_col {
                        pes[i + 1].f_fifo.push(tk);
                        stats.token_pushes += 1;
                        wake(nxt, park_cat, park_need, i + 1, need::F_TOKEN);
                    }
                }

                if wf_was_empty && !pes[i].wf_fifo.is_empty() {
                    n_mac_idle -= 1;
                    wf_busy.push(i as u32);
                }
                if pes[i].ds_done {
                    if pes[i].wf_fifo.is_empty() {
                        n_mac_idle -= 1;
                        finishing.push(i as u32);
                    }
                } else if out.progressed {
                    nxt[wi] |= 1u64 << b;
                } else {
                    let cat = match out.stall {
                        Stall::Starved => PARK_STARVED,
                        Stall::OutFull => PARK_OUT_FULL,
                        Stall::WfFull => PARK_WF_FULL,
                        Stall::None => {
                            debug_assert!(false, "no-progress step named no stall");
                            PARK_STARVED
                        }
                    };
                    park_cat[i] = cat;
                    park_need[i] = out.need;
                    fresh[cat as usize] += 1;
                }
            }
        }

        // 3. Parked PEs accrue this cycle's stall counters in O(1);
        //    PEs that parked during this cycle start accruing next cycle.
        stats.stall_starved += counts[PARK_STARVED as usize];
        stats.stall_out_full += counts[PARK_OUT_FULL as usize];
        stats.stall_wf_full += counts[PARK_WF_FULL as usize];
        for k in 1..4 {
            counts[k] += fresh[k];
            fresh[k] = 0;
        }

        // 4. MAC tick every `ratio` DS cycles.
        mac_countdown -= 1;
        if mac_countdown == 0 {
            mac_countdown = ratio;
            stats.mac_idle += n_mac_idle;
            for &j in finishing.iter() {
                let pe = &mut pes[j as usize];
                pe.compute_done = true;
                pe.finish_ds_cycle = ds_cycle;
                remaining -= 1;
            }
            finishing.clear();
            let mut k = 0;
            while k < wf_busy.len() {
                let j = wf_busy[k] as usize;
                let ops = pes[j].wf_fifo.pop().expect("busy implies non-empty");
                if ops > 1 {
                    // multi-op pair occupies the head for another MAC cycle
                    pes[j].wf_fifo.push(ops - 1);
                }
                if park_cat[j] == PARK_WF_FULL {
                    // freed WF space: the DS can emit again next cycle
                    nxt[j >> 6] |= 1u64 << (j & 63);
                }
                if pes[j].wf_fifo.is_empty() {
                    wf_busy.swap_remove(k);
                    if pes[j].ds_done {
                        finishing.push(j as u32);
                    } else {
                        n_mac_idle += 1;
                    }
                } else {
                    k += 1;
                }
            }
        }

        ds_cycle += 1;
        if ds_cycle > CYCLE_LIMIT {
            deadlock_panic(remaining);
        }
        if remaining == 0 {
            break;
        }

        // 5. Skip-ahead: if no PE will step next cycle and no source can
        //    inject, nothing changes until the next MAC tick — batch the
        //    idle cycles' stall accounting and jump.
        if nxt.iter().all(|&w| w == 0) {
            let mut injectable = false;
            for &r in live_rows.iter() {
                if pes[r as usize * cols].f_fifo.has_space() {
                    injectable = true;
                    break;
                }
            }
            if !injectable {
                for &c in live_cols.iter() {
                    if pes[c as usize].w_fifo.has_space() {
                        injectable = true;
                        break;
                    }
                }
            }
            if !injectable {
                if wf_busy.is_empty() && finishing.is_empty() {
                    // No event source left at all.
                    deadlock_panic(remaining);
                }
                let skip = mac_countdown - 1;
                if skip > 0 {
                    stats.stall_starved += skip * counts[PARK_STARVED as usize];
                    stats.stall_out_full += skip * counts[PARK_OUT_FULL as usize];
                    stats.stall_wf_full += skip * counts[PARK_WF_FULL as usize];
                    ds_cycle += skip;
                    mac_countdown = 1;
                    if ds_cycle > CYCLE_LIMIT {
                        deadlock_panic(remaining);
                    }
                }
            }
        }

        // `cur` is fully drained (all zero); it becomes the next cycle's
        // empty `nxt`, and the queued `nxt` becomes `cur`.
        std::mem::swap(cur, nxt);
    }

    // --- Result forwarding: each column drains its R results in row
    //     order, one per MAC cycle (Section 4.1).
    let mut max_drain_mac: u64 = 0;
    for c in 0..cols {
        let mut t: u64 = 0;
        for r in 0..rows {
            let fin_mac = pes[r * cols + c].finish_ds_cycle / ratio + 1;
            t = (t + 1).max(fin_mac + 1);
        }
        max_drain_mac = max_drain_mac.max(t);
    }
    stats.ds_cycles = ds_cycle.max(max_drain_mac * ratio);

    // --- Buffer traffic accounting (CE array model).
    let traffic = ce::account(tile, ce_enabled);
    ce::apply(&mut stats, &traffic);

    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapping::{build_tile, LayerMapping, TileSource};
    use crate::config::FifoDepths;
    use crate::models::LayerDesc;
    use crate::sim::reference::simulate_tile_reference;

    fn layer() -> LayerDesc {
        LayerDesc::new("t", 8, 8, 32, 3, 3, 16, 1, 1)
    }

    fn synth_tile(fd: f64, wd: f64, rows: usize, cols: usize) -> TileJob {
        let m = LayerMapping::new(&layer(), rows, cols);
        build_tile(
            &m,
            m.n_col_tiles(), // interior tile
            &TileSource::Synthetic {
                feature_density: fd,
                weight_density: wd,
                clustered: false,
            },
            0.0,
            7,
        )
    }

    #[test]
    fn single_pe_tile_completes() {
        let tile = synth_tile(0.5, 0.5, 1, 1);
        let cfg = ArrayConfig::new(1, 1);
        let s = simulate_tile(&tile, &cfg, true);
        assert!(s.ds_cycles > 0);
        assert_eq!(s.results, 1);
        assert_eq!(s.mac_ops, tile.must_macs());
    }

    #[test]
    fn mac_ops_equal_must_macs_exactly() {
        // The DS merge must find EVERY aligned pair, no more, no less —
        // the core correctness property of the architecture.
        for (fd, wd) in [(0.2, 0.2), (0.5, 0.3), (0.9, 0.9), (1.0, 1.0)] {
            let tile = synth_tile(fd, wd, 4, 4);
            let cfg = ArrayConfig::new(4, 4);
            let s = simulate_tile(&tile, &cfg, true);
            assert_eq!(
                s.mac_ops,
                tile.must_macs(),
                "density ({fd},{wd}): {} vs {}",
                s.mac_ops,
                tile.must_macs()
            );
        }
    }

    #[test]
    fn bounded_fifos_complete_without_deadlock() {
        let tile = synth_tile(0.6, 0.6, 8, 8);
        for depth in [1, 2, 4, 8] {
            let cfg =
                ArrayConfig::new(8, 8).with_fifo(FifoDepths::uniform(depth));
            let s = simulate_tile(&tile, &cfg, true);
            assert_eq!(s.mac_ops, tile.must_macs(), "depth {depth}");
        }
    }

    #[test]
    fn deeper_fifos_never_slower() {
        let tile = synth_tile(0.5, 0.5, 8, 8);
        let cycles = |d: FifoDepths| {
            simulate_tile(&tile, &ArrayConfig::new(8, 8).with_fifo(d), true)
                .ds_cycles
        };
        let d2 = cycles(FifoDepths::uniform(2));
        let d4 = cycles(FifoDepths::uniform(4));
        let d8 = cycles(FifoDepths::uniform(8));
        let inf = cycles(FifoDepths::infinite());
        assert!(d4 <= d2, "(4,4,4) {d4} vs (2,2,2) {d2}");
        assert!(d8 <= d4);
        assert!(inf <= d8);
    }

    #[test]
    fn higher_ds_ratio_fewer_wall_cycles() {
        // Higher DS frequency = more DS cycles per MAC cycle, so the same
        // tile takes fewer *MAC* cycles (wall time at fixed MAC clock).
        let tile = synth_tile(0.4, 0.4, 8, 8);
        let wall = |ratio: u32| {
            let cfg = ArrayConfig::new(8, 8)
                .with_fifo(FifoDepths::infinite())
                .with_ratio(ratio);
            let s = simulate_tile(&tile, &cfg, true);
            s.ds_cycles as f64 / ratio as f64
        };
        let w1 = wall(1);
        let w4 = wall(4);
        assert!(w4 < w1, "ratio 4 wall {w4} vs ratio 1 wall {w1}");
    }

    #[test]
    fn sparser_tiles_run_faster() {
        let cfg = ArrayConfig::new(8, 8);
        let sparse = simulate_tile(&synth_tile(0.2, 0.2, 8, 8), &cfg, true);
        let dense = simulate_tile(&synth_tile(1.0, 1.0, 8, 8), &cfg, true);
        assert!(
            sparse.ds_cycles * 2 < dense.ds_cycles,
            "sparse {} dense {}",
            sparse.ds_cycles,
            dense.ds_cycles
        );
    }

    #[test]
    fn partial_edge_tile() {
        // 5 rows x 3 cols on an 8x8 array
        let m = LayerMapping::new(&layer(), 5, 3);
        let tile = build_tile(
            &m,
            0,
            &TileSource::Synthetic {
                feature_density: 0.5,
                weight_density: 0.5,
                clustered: false,
            },
            0.0,
            1,
        );
        let cfg = ArrayConfig::new(8, 8);
        let s = simulate_tile(&tile, &cfg, true);
        assert_eq!(s.results, 15);
        assert_eq!(s.mac_ops, tile.must_macs());
    }

    #[test]
    fn mixed_precision_tile_more_ops_and_cycles() {
        let m = LayerMapping::new(&layer(), 8, 8);
        let src = TileSource::Synthetic {
            feature_density: 1.0,
            weight_density: 1.0,
            clustered: false,
        };
        let plain = build_tile(&m, 0, &src, 0.0, 3);
        let mixed = build_tile(&m, 0, &src, 0.10, 3);
        let cfg = ArrayConfig::new(8, 8);
        let sp = simulate_tile(&plain, &cfg, true);
        let sm = simulate_tile(&mixed, &cfg, true);
        assert!(sm.mac_ops > sp.mac_ops);
        assert!(sm.ds_cycles >= sp.ds_cycles);
        assert_eq!(sm.mac_ops, mixed.must_macs());
    }

    #[test]
    fn stats_internally_consistent() {
        let tile = synth_tile(0.5, 0.5, 8, 8);
        let cfg = ArrayConfig::new(8, 8);
        let s = simulate_tile(&tile, &cfg, true);
        assert_eq!(s.pairs, s.mac_ops, "8-bit only: 1 op per pair");
        assert!(s.f_tokens > 0 && s.w_tokens > 0);
        // every injected token is forwarded through (cols-1) PEs per row
        assert!(s.token_pushes > s.f_tokens);
        assert_eq!(s.fb_reads_ce + s.ce_fifo_reads, s.fb_reads_no_ce);
    }

    #[test]
    fn event_engine_matches_reference_spot_checks() {
        // Broad randomized coverage lives in tests/sim_equivalence.rs;
        // these pin the headline configurations in-unit.
        for (fd, wd, rows, cols) in
            [(0.35, 0.35, 8, 8), (0.2, 0.6, 4, 7), (1.0, 1.0, 4, 4)]
        {
            let tile = synth_tile(fd, wd, rows, cols);
            for depth in [2usize, 4, 8] {
                let cfg = ArrayConfig::new(rows, cols)
                    .with_fifo(FifoDepths::uniform(depth));
                let fast = simulate_tile(&tile, &cfg, true);
                let slow = simulate_tile_reference(&tile, &cfg, true);
                assert_eq!(fast, slow, "({fd},{wd}) {rows}x{cols} depth{depth}");
            }
            let cfg =
                ArrayConfig::new(rows, cols).with_fifo(FifoDepths::infinite());
            assert_eq!(
                simulate_tile(&tile, &cfg, true),
                simulate_tile_reference(&tile, &cfg, true),
                "infinite depth"
            );
        }
    }

    #[test]
    fn scratch_reuse_across_configs_is_clean() {
        // One scratch, wildly different consecutive configurations: the
        // reset path must leave no state behind.
        let mut scratch = SimScratch::new();
        let tile_a = synth_tile(0.3, 0.3, 8, 8);
        let tile_b = synth_tile(0.9, 0.9, 3, 5);
        let cfgs = [
            ArrayConfig::new(8, 8).with_fifo(FifoDepths::infinite()),
            ArrayConfig::new(8, 8).with_fifo(FifoDepths::uniform(2)),
            ArrayConfig::new(8, 8).with_ratio(1),
        ];
        for cfg in &cfgs {
            let warm = simulate_tile_with_scratch(&tile_a, cfg, true, &mut scratch);
            assert_eq!(warm, simulate_tile_reference(&tile_a, cfg, true));
            let warm_b =
                simulate_tile_with_scratch(&tile_b, cfg, true, &mut scratch);
            assert_eq!(warm_b, simulate_tile_reference(&tile_b, cfg, true));
        }
    }
}
