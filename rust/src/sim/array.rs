//! The systolic-array tile simulator: R×C PEs stepped cycle-by-cycle at
//! the DS clock, with weight flows travelling down columns, feature flows
//! travelling right along rows, MAC units ticking every `ds_ratio`
//! cycles, and in-order result forwarding per column (Section 4.1's RF
//! stall semantics).
//!
//! One call simulates one *tile* (one array pass over R output positions
//! × C kernels); layer totals are extrapolated by the coordinator from a
//! tile sample (DESIGN.md §5).

use super::ce;
use super::pe::Pe;
use super::stats::TileStats;
use crate::compiler::mapping::TileJob;
use crate::config::ArrayConfig;

/// Hard safety limit: no realistic tile needs this many DS cycles; hitting
/// it means a dataflow deadlock (a bug), so we panic loudly.
const CYCLE_LIMIT: u64 = 50_000_000;

/// Simulate one tile; returns its event counters.
pub fn simulate_tile(tile: &TileJob, cfg: &ArrayConfig, ce_enabled: bool) -> TileStats {
    let rows = tile.active_rows();
    let cols = tile.active_cols();
    assert!(rows > 0 && cols > 0, "empty tile");
    assert!(
        rows <= cfg.rows && cols <= cfg.cols,
        "tile {}x{} exceeds array {}x{}",
        rows,
        cols,
        cfg.rows,
        cfg.cols
    );
    let ratio = cfg.ds_ratio.max(1) as u64;
    let n_groups = tile.n_groups as u32;

    let mut stats = TileStats::default();
    stats.dense_macs = tile.dense_macs();
    stats.results = (rows * cols) as u64;

    // Flatten the streams (EOK on weight kernels).
    let f_src: Vec<Vec<u32>> = tile
        .features
        .iter()
        .map(|s| s.to_flow(false).tokens.iter().map(|t| t.0).collect())
        .collect();
    let w_src: Vec<Vec<u32>> = tile
        .weights
        .iter()
        .map(|s| s.to_flow(true).tokens.iter().map(|t| t.0).collect())
        .collect();
    let mut f_idx = vec![0usize; rows];
    let mut w_idx = vec![0usize; cols];

    let mut pes: Vec<Pe> = (0..rows * cols)
        .map(|_| Pe::new(cfg.fifo, n_groups))
        .collect();

    let mut ds_cycle: u64 = 0;
    let mut remaining = rows * cols;
    while remaining > 0 {
        // 1. Source injection: the CE array (features) and WB (weights)
        //    deliver one token per DS cycle per edge PE — Section 4.4:
        //    "The CE array runs at the same frequency as DS component".
        for r in 0..rows {
            if f_idx[r] < f_src[r].len() && pes[r * cols].f_fifo.has_space() {
                pes[r * cols].f_fifo.push(f_src[r][f_idx[r]]);
                f_idx[r] += 1;
                stats.f_tokens += 1;
            }
        }
        for c in 0..cols {
            if w_idx[c] < w_src[c].len() && pes[c].w_fifo.has_space() {
                pes[c].w_fifo.push(w_src[c][w_idx[c]]);
                w_idx[c] += 1;
                stats.w_tokens += 1;
            }
        }

        // 2. DS steps in reverse raster order so a token forwarded this
        //    cycle cannot hop multiple PEs within the same cycle.
        //    (index arithmetic kept additive — no div/mod in the hot loop,
        //    and certainly-stalled PEs skipped cheaply: EXPERIMENTS.md §Perf)
        let mut idx = rows * cols;
        for r in (0..rows).rev() {
            for c in (0..cols).rev() {
                idx -= 1;
                if pes[idx].ds_done {
                    continue;
                }
                let down_ok = r + 1 >= rows || pes[idx + cols].w_fifo.has_space();
                let right_ok = c + 1 >= cols || pes[idx + 1].f_fifo.has_space();
                let fwd = pes[idx].ds_step(down_ok, right_ok, &mut stats);
                if let Some(t) = fwd.w {
                    if r + 1 < rows {
                        pes[idx + cols].w_fifo.push(t);
                        stats.token_pushes += 1;
                    }
                }
                if let Some(t) = fwd.f {
                    if c + 1 < cols {
                        pes[idx + 1].f_fifo.push(t);
                        stats.token_pushes += 1;
                    }
                }
            }
        }

        // 3. MAC tick every `ratio` DS cycles.
        if ds_cycle % ratio == ratio - 1 {
            for pe in pes.iter_mut() {
                let was_done = pe.compute_done;
                pe.mac_step(ds_cycle, &mut stats);
                if pe.compute_done && !was_done {
                    remaining -= 1;
                }
            }
        }

        ds_cycle += 1;
        if ds_cycle > CYCLE_LIMIT {
            panic!(
                "tile simulation exceeded {CYCLE_LIMIT} DS cycles \
                 ({remaining} PEs unfinished) — dataflow deadlock"
            );
        }
    }

    // 4. Result forwarding: each column drains its R results in row
    //    order, one per MAC cycle; a PE that finished early stalls its RF
    //    until its predecessors' results have passed (Section 4.1).
    let mut max_drain_mac: u64 = 0;
    for c in 0..cols {
        let mut t: u64 = 0;
        for r in 0..rows {
            let fin_mac = pes[r * cols + c].finish_ds_cycle / ratio + 1;
            t = (t + 1).max(fin_mac + 1);
        }
        max_drain_mac = max_drain_mac.max(t);
    }
    stats.ds_cycles = ds_cycle.max(max_drain_mac * ratio);

    // 5. Buffer traffic accounting (CE array model).
    let traffic = ce::account(tile, ce_enabled);
    ce::apply(&mut stats, &traffic);

    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapping::{build_tile, LayerMapping, TileSource};
    use crate::config::FifoDepths;
    use crate::models::LayerDesc;

    fn layer() -> LayerDesc {
        LayerDesc::new("t", 8, 8, 32, 3, 3, 16, 1, 1)
    }

    fn synth_tile(fd: f64, wd: f64, rows: usize, cols: usize) -> TileJob {
        let m = LayerMapping::new(&layer(), rows, cols);
        build_tile(
            &m,
            m.n_col_tiles(), // interior tile
            &TileSource::Synthetic {
                feature_density: fd,
                weight_density: wd,
                clustered: false,
            },
            0.0,
            7,
        )
    }

    #[test]
    fn single_pe_tile_completes() {
        let tile = synth_tile(0.5, 0.5, 1, 1);
        let cfg = ArrayConfig::new(1, 1);
        let s = simulate_tile(&tile, &cfg, true);
        assert!(s.ds_cycles > 0);
        assert_eq!(s.results, 1);
        assert_eq!(s.mac_ops, tile.must_macs());
    }

    #[test]
    fn mac_ops_equal_must_macs_exactly() {
        // The DS merge must find EVERY aligned pair, no more, no less —
        // the core correctness property of the architecture.
        for (fd, wd) in [(0.2, 0.2), (0.5, 0.3), (0.9, 0.9), (1.0, 1.0)] {
            let tile = synth_tile(fd, wd, 4, 4);
            let cfg = ArrayConfig::new(4, 4);
            let s = simulate_tile(&tile, &cfg, true);
            assert_eq!(
                s.mac_ops,
                tile.must_macs(),
                "density ({fd},{wd}): {} vs {}",
                s.mac_ops,
                tile.must_macs()
            );
        }
    }

    #[test]
    fn bounded_fifos_complete_without_deadlock() {
        let tile = synth_tile(0.6, 0.6, 8, 8);
        for depth in [1, 2, 4, 8] {
            let cfg =
                ArrayConfig::new(8, 8).with_fifo(FifoDepths::uniform(depth));
            let s = simulate_tile(&tile, &cfg, true);
            assert_eq!(s.mac_ops, tile.must_macs(), "depth {depth}");
        }
    }

    #[test]
    fn deeper_fifos_never_slower() {
        let tile = synth_tile(0.5, 0.5, 8, 8);
        let cycles = |d: FifoDepths| {
            simulate_tile(&tile, &ArrayConfig::new(8, 8).with_fifo(d), true)
                .ds_cycles
        };
        let d2 = cycles(FifoDepths::uniform(2));
        let d4 = cycles(FifoDepths::uniform(4));
        let d8 = cycles(FifoDepths::uniform(8));
        let inf = cycles(FifoDepths::infinite());
        assert!(d4 <= d2, "(4,4,4) {d4} vs (2,2,2) {d2}");
        assert!(d8 <= d4);
        assert!(inf <= d8);
    }

    #[test]
    fn higher_ds_ratio_fewer_wall_cycles() {
        // Higher DS frequency = more DS cycles per MAC cycle, so the same
        // tile takes fewer *MAC* cycles (wall time at fixed MAC clock).
        let tile = synth_tile(0.4, 0.4, 8, 8);
        let wall = |ratio: u32| {
            let cfg = ArrayConfig::new(8, 8)
                .with_fifo(FifoDepths::infinite())
                .with_ratio(ratio);
            let s = simulate_tile(&tile, &cfg, true);
            s.ds_cycles as f64 / ratio as f64
        };
        let w1 = wall(1);
        let w4 = wall(4);
        assert!(w4 < w1, "ratio 4 wall {w4} vs ratio 1 wall {w1}");
    }

    #[test]
    fn sparser_tiles_run_faster() {
        let cfg = ArrayConfig::new(8, 8);
        let sparse = simulate_tile(&synth_tile(0.2, 0.2, 8, 8), &cfg, true);
        let dense = simulate_tile(&synth_tile(1.0, 1.0, 8, 8), &cfg, true);
        assert!(
            sparse.ds_cycles * 2 < dense.ds_cycles,
            "sparse {} dense {}",
            sparse.ds_cycles,
            dense.ds_cycles
        );
    }

    #[test]
    fn partial_edge_tile() {
        // 5 rows x 3 cols on an 8x8 array
        let m = LayerMapping::new(&layer(), 5, 3);
        let tile = build_tile(
            &m,
            0,
            &TileSource::Synthetic {
                feature_density: 0.5,
                weight_density: 0.5,
                clustered: false,
            },
            0.0,
            1,
        );
        let cfg = ArrayConfig::new(8, 8);
        let s = simulate_tile(&tile, &cfg, true);
        assert_eq!(s.results, 15);
        assert_eq!(s.mac_ops, tile.must_macs());
    }

    #[test]
    fn mixed_precision_tile_more_ops_and_cycles() {
        let m = LayerMapping::new(&layer(), 8, 8);
        let src = TileSource::Synthetic {
            feature_density: 1.0,
            weight_density: 1.0,
            clustered: false,
        };
        let plain = build_tile(&m, 0, &src, 0.0, 3);
        let mixed = build_tile(&m, 0, &src, 0.10, 3);
        let cfg = ArrayConfig::new(8, 8);
        let sp = simulate_tile(&plain, &cfg, true);
        let sm = simulate_tile(&mixed, &cfg, true);
        assert!(sm.mac_ops > sp.mac_ops);
        assert!(sm.ds_cycles >= sp.ds_cycles);
        assert_eq!(sm.mac_ops, mixed.must_macs());
    }

    #[test]
    fn stats_internally_consistent() {
        let tile = synth_tile(0.5, 0.5, 8, 8);
        let cfg = ArrayConfig::new(8, 8);
        let s = simulate_tile(&tile, &cfg, true);
        assert_eq!(s.pairs, s.mac_ops, "8-bit only: 1 op per pair");
        assert!(s.f_tokens > 0 && s.w_tokens > 0);
        // every injected token is forwarded through (cols-1) PEs per row
        assert!(s.token_pushes > s.f_tokens);
        assert_eq!(s.fb_reads_ce + s.ce_fifo_reads, s.fb_reads_no_ce);
    }
}
