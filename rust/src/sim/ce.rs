//! Collective Element array — overlap reuse between adjacent PE rows
//! (Section 4.4, Fig. 8).
//!
//! Each CE holds exactly one data group in an internal FIFO. When row r
//! needs a group that a neighbouring CE already holds (because an
//! adjacent output position's window overlaps), the group is served from
//! the CE chain instead of re-read from the feature buffer. The paper's
//! Fig. 13 metrics — reduction in FB *accesses* and FB *capacity* — are
//! computed here from the per-row group reference lists of a tile.
//!
//! The CE chain only spans the rows of one tile (one array pass), so
//! reuse is bounded by the array height: smaller arrays break the
//! transmission chain more often (the paper's observation that larger
//! PE arrays obtain slightly higher reduction).

use std::collections::HashMap;

use crate::compiler::groups::{GroupedStream, PAD_GROUP};
use crate::compiler::mapping::TileJob;
use crate::compiler::Token;
use crate::sim::stats::TileStats;

/// Buffer-traffic accounting for one tile.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CeTraffic {
    /// FB group reads without CE reuse: one per (row, group) reference.
    pub fb_reads_no_ce: u64,
    /// FB group reads with CE reuse: one per distinct group in the tile.
    pub fb_reads_ce: u64,
    /// References served from CE-internal FIFOs instead of FB.
    pub ce_fifo_reads: u64,
    /// WB group reads (weights have no overlap; one per kernel group).
    pub wb_reads: u64,
    /// FB bytes that must be resident without CE (per-row copies of the
    /// compressed streams — the "three separate FBs as three copies"
    /// arrangement of Section 4.4).
    pub fb_bytes_no_ce: u64,
    /// FB bytes resident with CE (each distinct group stored once).
    pub fb_bytes_ce: u64,
    /// Same two metrics for a *naive dense* buffer (uncompressed 8-bit).
    pub fb_bytes_naive: u64,
}

/// Compressed size in bytes of one group's token list (13-bit feature
/// tokens, rounded to bits then bytes at the buffer level).
fn group_feature_bytes(tokens: &[Token]) -> u64 {
    (tokens.len() as u64 * Token::FEATURE_BITS as u64).div_ceil(8)
}

fn group_weight_bytes(tokens: &[Token]) -> u64 {
    (tokens.len() as u64 * Token::WEIGHT_BITS as u64).div_ceil(8)
}

/// Account buffer traffic for a tile. Rows' feature streams are scanned
/// in lockstep "periods" (Fig. 8): within a period, each distinct group
/// is loaded from FB once by the first CE that needs it and passed down
/// the chain to the other rows referencing it.
pub fn account(tile: &TileJob, ce_enabled: bool) -> CeTraffic {
    let mut t = CeTraffic::default();

    // --- weights: one WB read per kernel group (broadcast down the
    // column by the systolic flow itself, so no duplicate reads).
    for w in &tile.weights {
        t.wb_reads += w.groups.len() as u64;
    }

    // --- features
    let mut distinct: HashMap<u64, u64> = HashMap::new();
    for f in &tile.features {
        for g in &f.groups {
            if g.fb_group == PAD_GROUP {
                continue; // padding is materialized by the CE, not read
            }
            t.fb_reads_no_ce += 1;
            t.fb_bytes_no_ce += group_feature_bytes(&g.tokens);
            t.fb_bytes_naive += crate::GROUP_LEN as u64; // dense 8-bit
            *distinct.entry(g.fb_group).or_insert(0) += 1;
        }
    }
    for (_, refs) in distinct.iter() {
        t.fb_reads_ce += 1;
        t.ce_fifo_reads += refs - 1;
    }
    // capacity with CE: each distinct group stored once
    let mut seen: HashMap<u64, u64> = HashMap::new();
    for f in &tile.features {
        for g in &f.groups {
            if g.fb_group != PAD_GROUP {
                seen.entry(g.fb_group)
                    .or_insert_with(|| group_feature_bytes(&g.tokens));
            }
        }
    }
    t.fb_bytes_ce = seen.values().sum();

    if !ce_enabled {
        // without CE every reference is an FB read and per-row copies
        // are resident
        t.fb_reads_ce = t.fb_reads_no_ce;
        t.ce_fifo_reads = 0;
        t.fb_bytes_ce = t.fb_bytes_no_ce;
    }
    t
}

/// Apply traffic to the tile's stats.
pub fn apply(stats: &mut TileStats, t: &CeTraffic) {
    stats.fb_reads_no_ce += t.fb_reads_no_ce;
    stats.fb_reads_ce += t.fb_reads_ce;
    stats.ce_fifo_reads += t.ce_fifo_reads;
    stats.wb_reads += t.wb_reads;
}

/// Compressed weight-stream bytes for WB capacity accounting.
pub fn weight_stream_bytes(w: &GroupedStream) -> u64 {
    w.groups.iter().map(|g| group_weight_bytes(&g.tokens)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapping::{build_tile, LayerMapping, TileSource};
    use crate::models::LayerDesc;

    fn tile(rows: usize) -> TileJob {
        let l = LayerDesc::new("t", 8, 8, 32, 3, 3, 16, 1, 1);
        let m = LayerMapping::new(&l, rows, 16);
        build_tile(
            &m,
            // interior row-tile to get plenty of overlap
            m.n_col_tiles(), // tile index 1*n_col_tiles+0 => rt=1, ct=0
            &TileSource::Synthetic {
                feature_density: 0.5,
                weight_density: 0.5,
                clustered: false,
            },
            0.0,
            1,
        )
    }

    #[test]
    fn ce_reduces_fb_reads() {
        let t = account(&tile(16), true);
        assert!(t.fb_reads_ce < t.fb_reads_no_ce);
        assert_eq!(t.fb_reads_ce + t.ce_fifo_reads, t.fb_reads_no_ce);
        // 3x3 stride-1 raster rows: roughly 3x reuse available
        let ratio = t.fb_reads_no_ce as f64 / t.fb_reads_ce as f64;
        assert!(ratio > 1.5, "reuse ratio only {ratio}");
    }

    #[test]
    fn ce_disabled_means_no_reduction() {
        let t = account(&tile(16), false);
        assert_eq!(t.fb_reads_ce, t.fb_reads_no_ce);
        assert_eq!(t.ce_fifo_reads, 0);
        assert_eq!(t.fb_bytes_ce, t.fb_bytes_no_ce);
    }

    #[test]
    fn capacity_reduction_with_ce() {
        let t = account(&tile(16), true);
        assert!(t.fb_bytes_ce < t.fb_bytes_no_ce);
        // compressed beats naive dense at 50% density? tokens are 13 bits
        // vs 8 dense bits/elem: 0.5*16*13 = 104 bits vs 128 bits
        assert!(t.fb_bytes_no_ce < t.fb_bytes_naive + t.fb_bytes_naive / 2);
    }

    #[test]
    fn larger_tile_height_more_reuse() {
        let small = account(&tile(4), true);
        let big = account(&tile(16), true);
        let r_small = small.fb_reads_no_ce as f64 / small.fb_reads_ce as f64;
        let r_big = big.fb_reads_no_ce as f64 / big.fb_reads_ce as f64;
        assert!(
            r_big > r_small,
            "bigger arrays should reuse more: {r_big} vs {r_small}"
        );
    }

    #[test]
    fn one_by_one_kernel_little_reuse() {
        // 1x1 kernels: adjacent output positions share no input groups,
        // the ResNet50 effect in Fig. 13.
        let l = LayerDesc::new("t", 8, 8, 32, 1, 1, 16, 1, 0);
        let m = LayerMapping::new(&l, 16, 16);
        let tile = build_tile(
            &m,
            0,
            &TileSource::Synthetic {
                feature_density: 0.5,
                weight_density: 0.5,
                clustered: false,
            },
            0.0,
            1,
        );
        let t = account(&tile, true);
        assert_eq!(
            t.fb_reads_ce, t.fb_reads_no_ce,
            "1x1 windows are disjoint"
        );
    }
}
