//! Serving summary — the network-level companion to the paper's
//! per-layer figures.
//!
//! One [`Grid`] declaration over the `batch` × `overlap` serving axes
//! for the three evaluated CNNs; each point reports the pipelined
//! metrics ([`crate::serve`]): request latency percentiles, throughput
//! at the modeled clock, and array occupancy. Like every figure sweep,
//! the summary renders from [`SweepResults`] and therefore inherits job
//! sharding, tile-memo reuse and `--resume`-able stores
//! (`s2engine sweep serving --out DIR --resume`).

use super::{Effort, TextTable};
use crate::backend::BackendKind;
use crate::config::ArrayConfig;
use crate::models::FeatureSubset;
use crate::serve::DensityModel;
use crate::sweep::{Grid, Job, Runner, Store};

/// The three CNNs the paper evaluates, in reporting order.
const PAPER_MODELS: [&str; 3] = ["alexnet", "vgg16", "resnet50"];
/// Batch-window sizes the summary sweeps.
const BATCHES: [usize; 3] = [1, 4, 8];
/// Double-buffer overlap fractions the summary sweeps.
const OVERLAPS: [f64; 2] = [0.0, 0.6];
/// The event-driven workloads of the second section: the spiking model
/// (timestep-decayed density) and the residual skip-connection DAG.
const EVENT_MODELS: [&str; 2] = ["snn", "resnet8"];
/// Per-request density models the dynamic section sweeps — the static
/// classic point plus a uniform band and an easy/hard bimodal mix.
const DENSITY_MODELS: [DensityModel; 3] = [
    DensityModel::Static,
    DensityModel::Uniform { lo: 0.1, hi: 0.6 },
    DensityModel::Bimodal {
        lo: 0.1,
        hi: 0.8,
        p: 0.3,
    },
];
/// The dynamic section's fixed serving point (loaded pipeline).
const EVENT_BATCH: usize = 4;
const EVENT_OVERLAP: f64 = 0.6;

/// Serving summary with a throwaway in-memory store. `backend` selects
/// the accelerator model serving the requests ([`crate::backend`]):
/// `s2engine sweep serving --backend scnn` renders this same summary
/// for the SCNN comparator. `requests` overrides the closed-loop
/// request count per point (`0` = the default `batch × SERVE_WINDOWS`
/// protocol) — the high-R regime the scheduler fast path unlocks.
pub fn serving(effort: Effort, seed: u64, backend: BackendKind, requests: usize) -> String {
    serving_in(effort, seed, backend, requests, &mut Store::in_memory())
}

/// [`serving`] against an explicit (possibly resumable) store.
pub fn serving_in(
    effort: Effort,
    seed: u64,
    backend: BackendKind,
    requests: usize,
    store: &mut Store,
) -> String {
    // the analytic comparators model 1024-multiplier machines;
    // evaluate them at PE parity (Table V's normalization) instead of
    // the S² default 16x16 working point
    let scale = backend.parity_scale().unwrap_or(16);
    let grid = Grid::new(effort, seed)
        .models(&PAPER_MODELS)
        .scales(&[(scale, scale)])
        .batches(&BATCHES)
        .overlaps(&OVERLAPS)
        .backends(&[backend])
        .requests(&[requests]);
    let res = Runner::new().run(&grid.plan(), store);
    let protocol = if requests == 0 {
        String::new()
    } else {
        format!(", {requests} requests")
    };
    let mut t = TextTable::new(
        format!(
            "Serving — pipelined network-level inference ({scale}x{scale}, \
             avg subset, backend {}{protocol})",
            backend.tag()
        ),
        &[
            "model", "batch", "overlap", "p50 lat", "p95 lat", "p99 lat",
            "images/s", "occupancy", "gain",
        ],
    );
    let array = ArrayConfig::new(scale, scale);
    let job = |m: &str, b: usize, ov: f64| {
        Job::subset(m, FeatureSubset::Average, array, true, seed, effort)
            .with_batch(b)
            .with_overlap(ov)
            .with_backend(backend)
            .with_requests(requests)
    };
    // records recovered from a store written before the serving axes
    // existed carry no serving metrics — render "n/a", never zeros or
    // a divide-by-zero gain
    let mut any_legacy = false;
    for m in PAPER_MODELS {
        let base_rec = res.get(&job(m, 1, 0.0));
        let base = base_rec.throughput;
        for b in BATCHES {
            for ov in OVERLAPS {
                let rec = res.get(&job(m, b, ov));
                let ok = rec.has_serving_metrics();
                any_legacy |= !ok;
                let cell = |v: String| if ok { v } else { "n/a".to_string() };
                let gain = if ok && base > 0.0 {
                    format!("{:.2}x", rec.throughput / base)
                } else {
                    "n/a".to_string()
                };
                t.row(vec![
                    m.to_string(),
                    b.to_string(),
                    format!("{ov:.1}"),
                    cell(ms(rec.p50_latency)),
                    cell(ms(rec.p95_latency)),
                    cell(ms(rec.p99_latency)),
                    cell(format!("{:.1}", rec.throughput)),
                    cell(format!("{:.2}", rec.occupancy)),
                    gain,
                ]);
            }
        }
    }
    let mut out = t.render()
        + "\nReading: batch=1/overlap=0 is the paper's per-layer serial mode \
           (gain 1.00x); batching amortizes weight residency and overlap \
           hides fill/drain under double buffering, raising images/s at the \
           cost of batch-forming latency in the tail percentiles.\n";
    if any_legacy {
        out.push_str(
            "n/a: point recovered from a pre-serving store (no serving \
             metrics recorded); rerun into a fresh --out to measure it.\n",
        );
    }
    out.push('\n');
    out.push_str(&dynamic_section(effort, seed, backend, requests, store));
    out
}

/// The second table: event workloads (spiking + residual DAG) under
/// per-request density models. The p99/p50 column is the input-
/// dependence signal — under a dynamic model, individual requests
/// realize different per-layer densities, so identical arrivals spread
/// into a latency distribution the static rows cannot produce.
fn dynamic_section(
    effort: Effort,
    seed: u64,
    backend: BackendKind,
    requests: usize,
    store: &mut Store,
) -> String {
    let scale = backend.parity_scale().unwrap_or(16);
    let grid = Grid::new(effort, seed)
        .models(&EVENT_MODELS)
        .scales(&[(scale, scale)])
        .batches(&[EVENT_BATCH])
        .overlaps(&[EVENT_OVERLAP])
        .backends(&[backend])
        .requests(&[requests])
        .density_models(&DENSITY_MODELS);
    let res = Runner::new().run(&grid.plan(), store);
    let mut t = TextTable::new(
        format!(
            "Serving — event workloads under per-request density \
             ({scale}x{scale}, batch {EVENT_BATCH}, overlap {EVENT_OVERLAP}, \
             backend {})",
            backend.tag()
        ),
        &[
            "model", "density", "p50 lat", "p99 lat", "p99/p50", "images/s",
        ],
    );
    let array = ArrayConfig::new(scale, scale);
    for m in EVENT_MODELS {
        for dm in DENSITY_MODELS {
            let job = Job::subset(m, FeatureSubset::Average, array, true, seed, effort)
                .with_batch(EVENT_BATCH)
                .with_overlap(EVENT_OVERLAP)
                .with_backend(backend)
                .with_requests(requests)
                .with_density(dm);
            let rec = res.get(&job);
            let ok = rec.has_serving_metrics();
            let cell = |v: String| if ok { v } else { "n/a".to_string() };
            let spread = if ok && rec.p50_latency > 0.0 {
                format!("{:.2}x", rec.p99_latency / rec.p50_latency)
            } else {
                "n/a".to_string()
            };
            t.row(vec![
                m.to_string(),
                dm.spec(),
                cell(ms(rec.p50_latency)),
                cell(ms(rec.p99_latency)),
                spread,
                cell(format!("{:.1}", rec.throughput)),
            ]);
        }
    }
    t.render()
        + "\nReading: `snn` is one inference as 4 timestep passes at \
           decaying spike density; `resnet8` carries real skip-connection \
           precedence edges. `static` holds every request at the model's \
           nominal density; the uniform band and bimodal easy/hard mix \
           sample each request's per-layer densities, so the tail ratio \
           p99/p50 widens with input-dependent work.\n"
}

/// Milliseconds with three decimals (latencies are modeled-clock
/// seconds).
fn ms(seconds: f64) -> String {
    format!("{:.3} ms", seconds * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_summary_covers_models_and_batches() {
        let effort = Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        };
        let s = serving(effort, 0xc0de_cafe_0021, BackendKind::S2, 0);
        for m in PAPER_MODELS {
            assert!(s.contains(m), "missing {m} in:\n{s}");
        }
        assert!(s.contains("p99 lat"));
        assert!(s.contains("images/s"));
        assert!(s.contains("1.00x"), "baseline gain row present");
    }

    #[test]
    fn serving_summary_runs_under_an_analytic_backend() {
        let effort = Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        };
        let s = serving(effort, 0xc0de_cafe_0023, BackendKind::Scnn, 0);
        assert!(s.contains("backend scnn"), "title names the backend:\n{s}");
        assert!(s.contains("1.00x"), "baseline gain row present");
        assert!(!s.contains("n/a"), "analytic run measures every point:\n{s}");
    }

    #[test]
    fn serving_summary_accepts_request_override() {
        // a non-zero request count names a distinct sweep point (the
        // |req suffix) and shows up in the table title
        let effort = Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        };
        let seed = 0xc0de_cafe_0024;
        let mut store = Store::in_memory();
        let s = serving_in(effort, seed, BackendKind::Scnn, 64, &mut store);
        assert!(s.contains("64 requests"), "title names the protocol:\n{s}");
        assert!(!s.contains("n/a"), "override points all measured:\n{s}");
        // the store keys carry the requests axis: a default-protocol
        // rerun shares nothing with the override run
        let before = store.len();
        let _ = serving_in(effort, seed, BackendKind::Scnn, 0, &mut store);
        assert!(store.len() > before, "default protocol is a distinct point");
    }

    #[test]
    fn dynamic_section_lists_event_workloads_with_spread() {
        let effort = Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        };
        let s = serving(effort, 0xc0de_cafe_0025, BackendKind::S2, 0);
        assert!(s.contains("event workloads"), "second section present:\n{s}");
        for m in EVENT_MODELS {
            assert!(s.contains(m), "missing {m} in:\n{s}");
        }
        assert!(s.contains("static"), "classic density row present");
        assert!(s.contains("uniform:0.1:0.6"), "uniform band row present");
        assert!(s.contains("bimodal:0.1:0.8:0.3"), "bimodal row present");
        assert!(s.contains("p99/p50"), "spread column present");
        assert!(!s.contains("n/a"), "fresh run measures every point:\n{s}");
    }

    #[test]
    fn dynamic_density_widens_the_tail_on_the_spiking_model() {
        // the acceptance signal behind the report column: identical
        // arrivals under a per-request density model realize different
        // work, so the p99 tail departs from the static point's
        let effort = Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        };
        let grid = Grid::new(effort, 0xc0de_cafe_0026)
            .models(&["snn"])
            .batches(&[EVENT_BATCH])
            .overlaps(&[EVENT_OVERLAP])
            .requests(&[32])
            .density_models(&DENSITY_MODELS);
        let res = Runner::new().run(&grid.plan(), &mut Store::in_memory());
        let stat = res.records()[0].clone();
        for dynamic in &res.records()[1..] {
            assert_ne!(
                stat.p99_latency, dynamic.p99_latency,
                "dynamic density must move the tail"
            );
            assert!(dynamic.p99_latency / dynamic.p50_latency >= 1.0);
        }
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(ms(1.25e-3), "1.250 ms");
        assert_eq!(ms(0.0), "0.000 ms");
    }

    #[test]
    fn legacy_store_records_render_na_not_inf() {
        // a record recovered from a pre-serving store (serving metrics
        // parsed as zeros) must render as n/a — not as measured zeros,
        // and not as an inf/NaN gain from the zero baseline
        use crate::config::ArrayConfig;
        use crate::models::FeatureSubset;
        use crate::sweep::Job;
        let effort = Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        };
        let seed = 0xc0de_cafe_0022;
        let mut warm = Store::in_memory();
        let _ = serving_in(effort, seed, BackendKind::S2, 0, &mut warm);
        let base_job = Job::subset(
            "alexnet",
            FeatureSubset::Average,
            ArrayConfig::new(16, 16),
            true,
            seed,
            effort,
        );
        let mut legacy = warm
            .get(base_job.key())
            .expect("baseline point simulated")
            .clone();
        legacy.p50_latency = 0.0;
        legacy.p95_latency = 0.0;
        legacy.p99_latency = 0.0;
        legacy.throughput = 0.0;
        legacy.occupancy = 0.0;
        assert!(!legacy.has_serving_metrics());
        let mut store = Store::in_memory();
        store.admit(legacy);
        let s = serving_in(effort, seed, BackendKind::S2, 0, &mut store);
        assert!(s.contains("n/a"), "legacy point must render n/a:\n{s}");
        assert!(s.contains("pre-serving store"), "footnote expected");
        assert!(!s.contains("inf") && !s.contains("NaN"), "no inf/NaN:\n{s}");
    }
}
