//! Backend head-to-head — the comparative study the paper's Tables
//! III/V make per-layer, lifted to the *network level*.
//!
//! One [`Grid`] declaration over the `backend` × `arrays` axes for the
//! three evaluated CNNs — plus the event workloads `snn` and `resnet8`
//! — at a fixed loaded serving point (batch 4,
//! overlap 0.6, data-parallel replication): every comparator —
//! S²Engine, the naive dense array, a representative gating design
//! (Cnvlutin-class), SCNN and SparTen — serves the *same* batched
//! request workload through the *same* pipeline/cluster schedulers, so
//! the table compares end-to-end serving behaviour (tail latency,
//! throughput, scale-out efficiency), not per-layer analytic walls.
//!
//! The array is 32×32 (1024 multipliers) to put S²Engine at PE-count
//! parity with the 1024-multiplier SCNN/SparTen models — the same
//! normalization Table V uses. Like every figure sweep, the summary
//! renders from [`crate::sweep::SweepResults`] and inherits job
//! sharding, tile-memo reuse and `--resume`-able stores
//! (`s2engine sweep backends --out DIR --resume`).

use super::{Effort, TextTable};
use crate::backend::BackendKind;
use crate::baseline::gating::Exploits;
use crate::config::ArrayConfig;
use crate::models::FeatureSubset;
use crate::sweep::{Grid, Job, Runner, Store};

/// The three CNNs the paper evaluates, in reporting order.
const PAPER_MODELS: [&str; 3] = ["alexnet", "vgg16", "resnet50"];
/// Event-driven additions to the roster: the spiking model (timestep
/// passes at very low density — the regime sparse architectures were
/// built for) and the residual skip-connection DAG.
const EVENT_MODELS: [&str; 2] = ["snn", "resnet8"];
/// The compared backends, in Table V's reporting order — the single
/// roster the head-to-head table and `benches/backend_compare.rs`
/// (and its required `BENCH_backends.json` metrics) share.
pub const BACKENDS: [BackendKind; 5] = [
    BackendKind::Naive,
    BackendKind::Gating(Exploits::SkipFeature),
    BackendKind::Scnn,
    BackendKind::SparTen,
    BackendKind::S2,
];
/// Cluster sizes: the single array and a 4-way data-parallel fleet.
const ARRAYS: [usize; 2] = [1, 4];
/// The fixed serving point (a loaded deployment, matching the cluster
/// summary's working point).
const BATCH: usize = 4;
const OVERLAP: f64 = 0.6;
/// PE-count parity with the 1024-multiplier analytic comparators.
const SCALE: usize = 32;

/// Backend head-to-head with a throwaway in-memory store. `requests`
/// overrides the closed-loop request count per point (`0` = the
/// default `batch × SERVE_WINDOWS` protocol) — the high-R regime the
/// scheduler fast path unlocks, where tail-latency and scale-out
/// conclusions stabilize.
pub fn backends(effort: Effort, seed: u64, requests: usize) -> String {
    backends_in(effort, seed, requests, &mut Store::in_memory())
}

/// [`backends`] against an explicit (possibly resumable) store.
pub fn backends_in(
    effort: Effort,
    seed: u64,
    requests: usize,
    store: &mut Store,
) -> String {
    let models: Vec<&str> = PAPER_MODELS.into_iter().chain(EVENT_MODELS).collect();
    let grid = Grid::new(effort, seed)
        .models(&models)
        .scales(&[(SCALE, SCALE)])
        .batches(&[BATCH])
        .overlaps(&[OVERLAP])
        .arrays(&ARRAYS)
        .backends(&BACKENDS)
        .requests(&[requests]);
    let res = Runner::new().run(&grid.plan(), store);
    let protocol = if requests == 0 {
        String::new()
    } else {
        format!(", {requests} requests")
    };
    let mut t = TextTable::new(
        format!(
            "Backends — head-to-head serving & scale-out (32x32 / 1024 muls, \
             avg subset, batch 4, overlap 0.6, data-parallel{protocol})"
        ),
        &[
            "model", "backend", "speedup", "onchip EE", "p99 lat (ms)",
            "img/s", "img/s x4", "scale eff x4",
        ],
    );
    let array = ArrayConfig::new(SCALE, SCALE);
    let job = |m: &str, b: BackendKind, n: usize| {
        Job::subset(m, FeatureSubset::Average, array, true, seed, effort)
            .with_batch(BATCH)
            .with_overlap(OVERLAP)
            .with_arrays(n)
            .with_backend(b)
            .with_requests(requests)
    };
    // records recovered from a store written before the serving/cluster
    // metrics existed carry zeros — render "n/a", never measurements
    let mut any_legacy = false;
    let fleet = ARRAYS[1];
    for m in PAPER_MODELS.into_iter().chain(EVENT_MODELS) {
        for b in BACKENDS {
            let one = res.get(&job(m, b, 1));
            let four = res.get(&job(m, b, fleet));
            let serving_ok = one.has_serving_metrics();
            let cluster_ok = four.has_cluster_metrics();
            any_legacy |= !serving_ok || !cluster_ok;
            let scell = |v: String| if serving_ok { v } else { "n/a".to_string() };
            let ccell = |v: String| if cluster_ok { v } else { "n/a".to_string() };
            t.row(vec![
                m.to_string(),
                b.tag().to_string(),
                format!("{:.2}x", one.speedup),
                format!("{:.2}x", one.onchip_ee),
                scell(format!("{:.3}", one.p99_latency * 1e3)),
                scell(format!("{:.1}", one.throughput)),
                // cluster throughput reconstructed from the stored
                // efficiency: requests/T_N = (requests/T₁) × N × eff
                ccell(format!(
                    "{:.1}",
                    four.throughput * four.scaleout_eff * fleet as f64
                )),
                ccell(format!("{:.2}", four.scaleout_eff)),
            ]);
        }
    }
    let mut out = t.render()
        + "\nReading: speedup and on-chip EE are vs the naive dense array on \
           the same workload (naive = 1.00x by construction). SparTen leads \
           on raw speed but pays prefix-sum/permute energy; SCNN loses \
           dense-mode speed to crossbar contention; S²Engine holds both \
           axes (Table V, network-level). The x4 columns replicate each \
           design data-parallel across four arrays under the same batched \
           workload.\n";
    if any_legacy {
        out.push_str(
            "n/a: point recovered from a store predating the serving/cluster \
             metrics; rerun into a fresh --out to measure it.\n",
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Effort {
        Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        }
    }

    #[test]
    fn head_to_head_covers_models_and_backends() {
        let s = backends(tiny(), 0xc0de_cafe_0070, 0);
        for m in PAPER_MODELS.into_iter().chain(EVENT_MODELS) {
            assert!(s.contains(m), "missing {m} in:\n{s}");
        }
        for b in BACKENDS {
            assert!(s.contains(b.tag()), "missing {} in:\n{s}", b.tag());
        }
        assert!(s.contains("1.00x"), "naive self-baseline row present");
        assert!(!s.contains("n/a"), "fresh run has no legacy points:\n{s}");
    }

    #[test]
    fn head_to_head_is_store_resumable() {
        // the same summary from a warm store reuses every point and
        // renders byte-identically (the backend axis keys are stable)
        let effort = tiny();
        let seed = 0xc0de_cafe_0071;
        let mut store = Store::in_memory();
        let first = backends_in(effort, seed, 0, &mut store);
        let expected =
            (PAPER_MODELS.len() + EVENT_MODELS.len()) * BACKENDS.len() * ARRAYS.len();
        assert_eq!(store.len(), expected);
        let second = backends_in(effort, seed, 0, &mut store);
        assert_eq!(first, second);
    }

    #[test]
    fn head_to_head_accepts_request_override() {
        // the --requests satellite: the same head-to-head at an explicit
        // request count names distinct sweep points and labels the title
        let s = backends(tiny(), 0xc0de_cafe_0072, 128);
        assert!(s.contains("128 requests"), "title names the protocol:\n{s}");
        assert!(!s.contains("n/a"), "override points all measured:\n{s}");
    }
}
