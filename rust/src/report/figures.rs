//! Figure regeneration: Figs. 3 and 10–17 of the paper. Each function
//! prints the series the paper plots (one row per x-value, one column
//! per curve).

use super::{fx, pct, Effort, TextTable};
use crate::baseline::scnn;
use crate::config::{ArrayConfig, FifoDepths, SimConfig};
use crate::coordinator::{Coordinator, ModelResult};
use crate::energy::area;
use crate::models::{zoo, FeatureSubset, Model};
use crate::sparsity;

fn run(
    model: &Model,
    array: ArrayConfig,
    effort: Effort,
    seed: u64,
    ce: bool,
    subset: FeatureSubset,
) -> ModelResult {
    let mut cfg = SimConfig::new(array).with_samples(effort.tile_samples);
    cfg.seed = seed;
    cfg.ce_enabled = ce;
    Coordinator::new(cfg).simulate_model_subset(model, subset)
}

/// Fig. 3: distribution of feature density and must-be-performed MAC
/// ratio per network (histogram mean ± std and deciles).
pub fn fig3(effort: Effort, seed: u64) -> String {
    let mut t = TextTable::new(
        "Fig. 3 — Feature density and must-MAC ratio distributions",
        &["model", "density mean", "density std", "must-MAC mean", "must-MAC std"],
    );
    for m in zoo::paper_models() {
        let s = sparsity::fig3(&m, effort.images, 50, seed);
        t.row(vec![
            m.name.clone(),
            format!("{:.3}", s.feature_density.mean()),
            format!("{:.3}", s.feature_density.std()),
            format!("{:.3}", s.must_mac.mean()),
            format!("{:.3}", s.must_mac.std()),
        ]);
    }
    t.render()
        + "\nPaper shape: densities centred at Table II values; AlexNet \
           visibly wider; must-MAC concentrated well below density.\n"
}

/// Fig. 10: PE-array speedup vs FIFO depth × DS:MAC frequency ratio
/// (16×16 array, average of the three CNNs).
pub fn fig10(effort: Effort, seed: u64) -> String {
    let depths = [
        FifoDepths::uniform(2),
        FifoDepths::uniform(4),
        FifoDepths::uniform(8),
        FifoDepths::infinite(),
    ];
    let ratios = [2u32, 4, 8];
    let mut t = TextTable::new(
        "Fig. 10 — Speedup vs FIFO depth and DS:MAC ratio (16x16)",
        &["FIFO depth", "ratio 2:1", "ratio 4:1", "ratio 8:1"],
    );
    let models: Vec<Model> = zoo::paper_models().iter().map(|m| effort.thin(m)).collect();
    for d in depths {
        let mut row = vec![d.label()];
        for r in ratios {
            let array = ArrayConfig::new(16, 16).with_fifo(d).with_ratio(r);
            let avg: f64 = models
                .iter()
                .map(|m| run(m, array, effort, seed, true, FeatureSubset::Average).speedup())
                .sum::<f64>()
                / models.len() as f64;
            row.push(fx(avg));
        }
        t.row(row);
    }
    t.render()
        + "\nPaper shape: ~1.5x from ratio 2->4, only ~1.1x from 4->8 \
           (saturation); ~1.2x from depth (2,2,2)->(4,4,4), ~1.1x further \
           to (8,8,8); (inf,inf,inf) is the ceiling.\n"
}

/// Fig. 11: normalized latency / on-chip energy / area efficiency vs
/// density (synthetic AlexNet, 32×32, vs naive and SCNN).
pub fn fig11(effort: Effort, seed: u64) -> String {
    let mut t = TextTable::new(
        "Fig. 11 — Normalized metrics vs density (32x32, synthetic AlexNet)",
        &[
            "density f/w",
            "S2 latency",
            "SCNN latency",
            "S2 energy",
            "SCNN energy",
            "S2 area-eff",
        ],
    );
    let base_model = zoo::synthetic_alexnet(1.0, 1.0);
    let model = effort.thin(&base_model);
    let array = ArrayConfig::new(32, 32);
    for d in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let mut cfg = SimConfig::new(array).with_samples(effort.tile_samples);
        cfg.seed = seed;
        let r = Coordinator::new(cfg).simulate_model_synthetic(&model, d, d);
        // normalized latency: S2 wall / naive wall (lower is better)
        let lat = r.total_s2_wall() / r.total_naive_wall();
        let energy = 1.0 / r.onchip_ee_improvement();
        let ae = r.area_efficiency_improvement();
        let sc = scnn::cost(model.total_macs(), d, d);
        let sc_lat = sc.mac_cycles as f64
            / (model.total_macs() as f64 / 1024.0); // vs dense ideal @1024 muls
        t.row(vec![
            format!("{d:.1}/{d:.1}"),
            format!("{lat:.3}"),
            format!("{sc_lat:.3}"),
            format!("{energy:.3}"),
            format!("{:.3}", sc.energy_per_dense_mac),
            fx(ae),
        ]);
    }
    t.render()
        + "\nPaper shape: S2 beats naive (latency < 1) everywhere below \
           ~0.7 density and beats SCNN's energy below ~0.5/0.5; at 1.0/1.0 \
           sparse designs pay overhead (latency/energy >= 1).\n"
}

/// Fig. 12: normalized latency vs 16-bit data ratio per FIFO depth
/// (dense synthetic AlexNet).
pub fn fig12(effort: Effort, seed: u64) -> String {
    let model = effort.thin(&zoo::synthetic_alexnet(1.0, 1.0));
    let mut t = TextTable::new(
        "Fig. 12 — Normalized latency vs 16-bit ratio",
        &["16-bit ratio", "(2,2,2)", "(4,4,4)", "(8,8,8)"],
    );
    let mut base = Vec::new();
    for depth in [2usize, 4, 8] {
        let array = ArrayConfig::new(16, 16).with_fifo(FifoDepths::uniform(depth));
        let mut cfg = SimConfig::new(array).with_samples(effort.tile_samples);
        cfg.seed = seed;
        base.push(
            Coordinator::new(cfg)
                .simulate_model_synthetic(&model, 1.0, 1.0)
                .total_s2_wall(),
        );
    }
    for r16 in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let mut row = vec![pct(r16)];
        for (i, depth) in [2usize, 4, 8].iter().enumerate() {
            let array =
                ArrayConfig::new(16, 16).with_fifo(FifoDepths::uniform(*depth));
            let mut cfg = SimConfig::new(array).with_samples(effort.tile_samples);
            cfg.seed = seed;
            cfg.ratio16 = r16;
            let wall = Coordinator::new(cfg)
                .simulate_model_synthetic(&model, 1.0, 1.0)
                .total_s2_wall();
            row.push(format!("{:.3}", wall / base[i]));
        }
        t.row(row);
    }
    t.render()
        + "\nPaper shape: latency grows smoothly with 16-bit ratio (the \
           shared 8-bit datapath absorbs splits); deeper FIFOs flatten \
           the curve.\n"
}

/// Fig. 13: reduction of buffer accesses and buffer capacity from the CE
/// array, per model and array scale.
pub fn fig13(effort: Effort, seed: u64) -> String {
    let mut t = TextTable::new(
        "Fig. 13 — CE-array reduction of FB accesses / capacity",
        &["model", "scale", "access reduction", "capacity reduction"],
    );
    for m in zoo::paper_models() {
        let model = effort.thin(&m);
        for scale in [16usize, 64] {
            let array = ArrayConfig::new(scale, scale);
            let r = run(&model, array, effort, seed, true, FeatureSubset::Average);
            // capacity reduction: naive dense per-row copies vs compressed
            // distinct groups — approximate with access reduction times the
            // compression ratio of the streams (13-bit tokens at density).
            let access = r.avg_buffer_access_reduction();
            let comp = 8.0 / (13.0 * r.layers[0].feature_density.max(0.05));
            let capacity = access * comp.min(3.0) / 1.6;
            t.row(vec![
                model.name.clone(),
                format!("{scale}x{scale}"),
                fx(access),
                fx(capacity),
            ]);
        }
    }
    t.render()
        + "\nPaper shape: large reduction for AlexNet/VGG16 (3x3-heavy), \
           much smaller for ResNet50 (1x1-heavy); slightly larger arrays \
           reduce slightly more.\n"
}

/// Fig. 14: speedup vs array scale × FIFO depth, with max/avg/min
/// feature-sparsity bands per model.
pub fn fig14(effort: Effort, seed: u64, scales: &[usize]) -> String {
    let mut t = TextTable::new(
        "Fig. 14 — Speedup vs scale and FIFO depth (bands: max/avg/min sparsity)",
        &["model", "scale", "depth", "max-spars.", "average", "min-spars."],
    );
    for m in zoo::paper_models() {
        let model = effort.thin(&m);
        for &scale in scales {
            for depth in [2usize, 4, 8] {
                let array =
                    ArrayConfig::new(scale, scale).with_fifo(FifoDepths::uniform(depth));
                let hi = run(&model, array, effort, seed, true, FeatureSubset::MaxSparsity);
                let avg = run(&model, array, effort, seed, true, FeatureSubset::Average);
                let lo = run(&model, array, effort, seed, true, FeatureSubset::MinSparsity);
                t.row(vec![
                    model.name.clone(),
                    format!("{scale}x{scale}"),
                    format!("({depth},{depth},{depth})"),
                    fx(hi.speedup()),
                    fx(avg.speedup()),
                    fx(lo.speedup()),
                ]);
            }
        }
    }
    t.render()
        + "\nPaper shape: ~3.2x average overall; larger arrays degrade \
           speedup slightly; AlexNet has the widest max/min band (widest \
           density distribution in Fig. 3).\n"
}

/// Fig. 15: on-chip energy breakdown with and without the CE array
/// (16×16, per model).
pub fn fig15(effort: Effort, seed: u64) -> String {
    let mut t = TextTable::new(
        "Fig. 15 — On-chip energy breakdown (pJ fractions), w/ and w/o CE",
        &["model", "CE", "MAC", "SRAM", "FIFO", "CE-arr", "other", "total (norm.)"],
    );
    for m in zoo::paper_models() {
        let model = effort.thin(&m);
        let array = ArrayConfig::new(16, 16);
        let with = run(&model, array, effort, seed, true, FeatureSubset::Average);
        let without = run(&model, array, effort, seed, false, FeatureSubset::Average);
        let wo_total = without.s2_energy().onchip.onchip_total();
        for (tag, r) in [("w/", &with), ("w/o", &without)] {
            let e = r.s2_energy().onchip;
            let tot = e.onchip_total();
            t.row(vec![
                model.name.clone(),
                tag.to_string(),
                pct(e.mac_pj / tot),
                pct(e.sram_pj / tot),
                pct(e.fifo_pj / tot),
                pct(e.ce_pj / tot),
                pct(e.other_pj / tot),
                format!("{:.3}", tot / wo_total),
            ]);
        }
    }
    t.render()
        + "\nPaper shape: CE cuts the SRAM (FB) slice substantially; MAC \
           and SRAM dominate; FIFO overhead visible but smaller than the \
           savings.\n"
}

/// Fig. 16: on-chip energy-efficiency improvement vs scale × depth.
pub fn fig16(effort: Effort, seed: u64, scales: &[usize]) -> String {
    let mut t = TextTable::new(
        "Fig. 16 — On-chip energy-efficiency improvement vs naive",
        &["model", "scale", "(2,2,2)", "(4,4,4)", "(8,8,8)"],
    );
    for m in zoo::paper_models() {
        let model = effort.thin(&m);
        for &scale in scales {
            let mut row = vec![model.name.clone(), format!("{scale}x{scale}")];
            for depth in [2usize, 4, 8] {
                let array =
                    ArrayConfig::new(scale, scale).with_fifo(FifoDepths::uniform(depth));
                let r = run(&model, array, effort, seed, true, FeatureSubset::Average);
                row.push(fx(r.onchip_ee_improvement()));
            }
            t.row(row);
        }
    }
    t.render()
        + "\nPaper shape: ~1.8x average, best (~1.9x) at depth (2,2,2); \
           improvement scales well with array size; CE contributes ~1.3x \
           (compare Fig. 15 w/o).\n"
}

/// Fig. 17: area-efficiency improvement vs scale × depth.
pub fn fig17(effort: Effort, seed: u64, scales: &[usize]) -> String {
    let mut t = TextTable::new(
        "Fig. 17 — Area-efficiency improvement vs naive",
        &["model", "scale", "(2,2,2)", "(4,4,4)", "(8,8,8)", "SCNN A.E."],
    );
    for m in zoo::paper_models() {
        let model = effort.thin(&m);
        for &scale in scales {
            let mut row = vec![model.name.clone(), format!("{scale}x{scale}")];
            for depth in [2usize, 4, 8] {
                let array =
                    ArrayConfig::new(scale, scale).with_fifo(FifoDepths::uniform(depth));
                let r = run(&model, array, effort, seed, true, FeatureSubset::Average);
                row.push(fx(r.area_efficiency_improvement()));
            }
            // SCNN AE vs naive at this workload (area-scaled)
            let sc = scnn::cost(model.total_macs(), model.feature_density, model.weight_density);
            let naive_cycles = model.total_macs() as f64 / 1024.0;
            let sc_speed = naive_cycles / sc.mac_cycles as f64;
            let naive_a = area::naive_area(&ArrayConfig::new(32, 32), 2 << 20);
            row.push(fx(sc_speed * naive_a / area::SCNN_AREA_MM2));
            t.row(row);
        }
    }
    t.render()
        + "\nPaper shape: ~2.9x average, larger for small arrays (SRAM \
           savings dominate) shrinking toward ~1.2x at 128x128; beats \
           SCNN's area efficiency.\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick() {
        let s = fig3(Effort::QUICK, 1);
        assert!(s.contains("alexnet") && s.contains("must-MAC"));
    }

    #[test]
    fn fig13_quick_resnet_lower() {
        let s = fig13(Effort::QUICK, 1);
        assert!(s.contains("resnet50"));
        // (shape assertions live in the integration tests)
    }
}
