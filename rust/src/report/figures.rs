//! Figure regeneration: Figs. 3 and 10–17 of the paper. Each function
//! prints the series the paper plots (one row per x-value, one column
//! per curve).
//!
//! Every simulation-backed figure (10–17) is a [`Grid`] declaration:
//! the figure names its axes, the [`Runner`] executes the expanded plan
//! (skipping points an optionally-supplied resumable [`Store`] already
//! holds), and the render loop reads the returned [`SweepResults`] —
//! looking points up by reconstructing the same [`Job`]s the grid
//! expands to. The `*_in` variants take an explicit store (the CLI's
//! `--out/--resume` path); the plain variants use a throwaway in-memory
//! store. Rendered output is identical either way, and identical to the
//! pre-sweep-engine hand-rolled loops.

use super::{fx, pct, Effort, TextTable};
use crate::baseline::scnn;
use crate::config::{ArrayConfig, FifoDepths};
use crate::energy::area;
use crate::models::{zoo, FeatureSubset};
use crate::sparsity;
use crate::sweep::{Grid, Job, Runner, Store, SweepResults};

/// The three CNNs the paper evaluates, in reporting order.
const PAPER_MODELS: [&str; 3] = ["alexnet", "vgg16", "resnet50"];

fn run_grid(grid: &Grid, store: &mut Store) -> SweepResults {
    Runner::new().run(&grid.plan(), store)
}

/// Fig. 3: distribution of feature density and must-be-performed MAC
/// ratio per network (histogram mean ± std and deciles).
pub fn fig3(effort: Effort, seed: u64) -> String {
    let mut t = TextTable::new(
        "Fig. 3 — Feature density and must-MAC ratio distributions",
        &["model", "density mean", "density std", "must-MAC mean", "must-MAC std"],
    );
    for m in zoo::paper_models() {
        let s = sparsity::fig3(&m, effort.images, 50, seed);
        t.row(vec![
            m.name.clone(),
            format!("{:.3}", s.feature_density.mean()),
            format!("{:.3}", s.feature_density.std()),
            format!("{:.3}", s.must_mac.mean()),
            format!("{:.3}", s.must_mac.std()),
        ]);
    }
    t.render()
        + "\nPaper shape: densities centred at Table II values; AlexNet \
           visibly wider; must-MAC concentrated well below density.\n"
}

/// Fig. 10: PE-array speedup vs FIFO depth × DS:MAC frequency ratio
/// (16×16 array, average of the three CNNs).
pub fn fig10(effort: Effort, seed: u64) -> String {
    fig10_in(effort, seed, &mut Store::in_memory())
}

/// [`fig10`] against an explicit (possibly resumable) store.
pub fn fig10_in(effort: Effort, seed: u64, store: &mut Store) -> String {
    let depths = [
        FifoDepths::uniform(2),
        FifoDepths::uniform(4),
        FifoDepths::uniform(8),
        FifoDepths::infinite(),
    ];
    let ratios = [2u32, 4, 8];
    let grid = Grid::new(effort, seed)
        .models(&PAPER_MODELS)
        .fifos(&depths)
        .ratios(&ratios);
    let res = run_grid(&grid, store);
    let mut t = TextTable::new(
        "Fig. 10 — Speedup vs FIFO depth and DS:MAC ratio (16x16)",
        &["FIFO depth", "ratio 2:1", "ratio 4:1", "ratio 8:1"],
    );
    for d in depths {
        let mut row = vec![d.label()];
        for r in ratios {
            let array = ArrayConfig::new(16, 16).with_fifo(d).with_ratio(r);
            let avg: f64 = PAPER_MODELS
                .iter()
                .map(|&m| {
                    res.get(&Job::subset(m, FeatureSubset::Average, array, true, seed, effort))
                        .speedup
                })
                .sum::<f64>()
                / PAPER_MODELS.len() as f64;
            row.push(fx(avg));
        }
        t.row(row);
    }
    t.render()
        + "\nPaper shape: ~1.5x from ratio 2->4, only ~1.1x from 4->8 \
           (saturation); ~1.2x from depth (2,2,2)->(4,4,4), ~1.1x further \
           to (8,8,8); (inf,inf,inf) is the ceiling.\n"
}

/// Fig. 11: normalized latency / on-chip energy / area efficiency vs
/// density (synthetic AlexNet, 32×32, vs naive and SCNN).
pub fn fig11(effort: Effort, seed: u64) -> String {
    fig11_in(effort, seed, &mut Store::in_memory())
}

/// [`fig11`] against an explicit (possibly resumable) store.
pub fn fig11_in(effort: Effort, seed: u64, store: &mut Store) -> String {
    let densities: Vec<(f64, f64)> = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
        .iter()
        .map(|&d| (d, d))
        .collect();
    let grid = Grid::new(effort, seed)
        .models(&["synthetic-alexnet"])
        .densities(&densities)
        .scales(&[(32, 32)]);
    let res = run_grid(&grid, store);
    let mut t = TextTable::new(
        "Fig. 11 — Normalized metrics vs density (32x32, synthetic AlexNet)",
        &[
            "density f/w",
            "S2 latency",
            "SCNN latency",
            "S2 energy",
            "SCNN energy",
            "S2 area-eff",
        ],
    );
    // the analytic SCNN comparator runs on the same thinned workload
    let model = effort.thin(&zoo::synthetic_alexnet(1.0, 1.0));
    let array = ArrayConfig::new(32, 32);
    for (d, _) in densities {
        let rec = res.get(&Job::synthetic(
            "synthetic-alexnet", d, d, array, 0.0, seed, effort,
        ));
        // normalized latency: S2 wall / naive wall (lower is better)
        let lat = rec.s2_wall / rec.naive_wall;
        let energy = 1.0 / rec.onchip_ee;
        let ae = rec.area_eff;
        let sc = scnn::cost(model.total_macs(), d, d);
        let sc_lat = sc.mac_cycles as f64
            / (model.total_macs() as f64 / 1024.0); // vs dense ideal @1024 muls
        t.row(vec![
            format!("{d:.1}/{d:.1}"),
            format!("{lat:.3}"),
            format!("{sc_lat:.3}"),
            format!("{energy:.3}"),
            format!("{:.3}", sc.energy_per_dense_mac),
            fx(ae),
        ]);
    }
    t.render()
        + "\nPaper shape: S2 beats naive (latency < 1) everywhere below \
           ~0.7 density and beats SCNN's energy below ~0.5/0.5; at 1.0/1.0 \
           sparse designs pay overhead (latency/energy >= 1).\n"
}

/// Fig. 12: normalized latency vs 16-bit data ratio per FIFO depth
/// (dense synthetic AlexNet).
pub fn fig12(effort: Effort, seed: u64) -> String {
    fig12_in(effort, seed, &mut Store::in_memory())
}

/// [`fig12`] against an explicit (possibly resumable) store.
pub fn fig12_in(effort: Effort, seed: u64, store: &mut Store) -> String {
    let depths = [2usize, 4, 8];
    let r16s = [0.1, 0.25, 0.5, 0.75, 1.0];
    let grid = Grid::new(effort, seed)
        .models(&["synthetic-alexnet"])
        .densities(&[(1.0, 1.0)])
        .fifos(&depths.map(FifoDepths::uniform))
        .ratio16(&[0.0, 0.1, 0.25, 0.5, 0.75, 1.0]);
    let res = run_grid(&grid, store);
    let job = |depth: usize, r16: f64| {
        let array = ArrayConfig::new(16, 16).with_fifo(FifoDepths::uniform(depth));
        Job::synthetic("synthetic-alexnet", 1.0, 1.0, array, r16, seed, effort)
    };
    let mut t = TextTable::new(
        "Fig. 12 — Normalized latency vs 16-bit ratio",
        &["16-bit ratio", "(2,2,2)", "(4,4,4)", "(8,8,8)"],
    );
    let base: Vec<f64> = depths
        .iter()
        .map(|&depth| res.get(&job(depth, 0.0)).s2_wall)
        .collect();
    for r16 in r16s {
        let mut row = vec![pct(r16)];
        for (i, depth) in depths.iter().enumerate() {
            let wall = res.get(&job(*depth, r16)).s2_wall;
            row.push(format!("{:.3}", wall / base[i]));
        }
        t.row(row);
    }
    t.render()
        + "\nPaper shape: latency grows smoothly with 16-bit ratio (the \
           shared 8-bit datapath absorbs splits); deeper FIFOs flatten \
           the curve.\n"
}

/// Fig. 13: reduction of buffer accesses and buffer capacity from the CE
/// array, per model and array scale.
pub fn fig13(effort: Effort, seed: u64) -> String {
    fig13_in(effort, seed, &mut Store::in_memory())
}

/// [`fig13`] against an explicit (possibly resumable) store.
pub fn fig13_in(effort: Effort, seed: u64, store: &mut Store) -> String {
    let scales = [16usize, 64];
    let grid = Grid::new(effort, seed)
        .models(&PAPER_MODELS)
        .scales(&scales.map(|s| (s, s)));
    let res = run_grid(&grid, store);
    let mut t = TextTable::new(
        "Fig. 13 — CE-array reduction of FB accesses / capacity",
        &["model", "scale", "access reduction", "capacity reduction"],
    );
    for m in PAPER_MODELS {
        for scale in scales {
            let array = ArrayConfig::new(scale, scale);
            let rec =
                res.get(&Job::subset(m, FeatureSubset::Average, array, true, seed, effort));
            // capacity reduction: naive dense per-row copies vs compressed
            // distinct groups — approximate with access reduction times the
            // compression ratio of the streams (13-bit tokens at density).
            let access = rec.access_reduction;
            let comp = 8.0 / (13.0 * rec.layer0_feature_density.max(0.05));
            let capacity = access * comp.min(3.0) / 1.6;
            t.row(vec![
                m.to_string(),
                format!("{scale}x{scale}"),
                fx(access),
                fx(capacity),
            ]);
        }
    }
    t.render()
        + "\nPaper shape: large reduction for AlexNet/VGG16 (3x3-heavy), \
           much smaller for ResNet50 (1x1-heavy); slightly larger arrays \
           reduce slightly more.\n"
}

/// Fig. 14: speedup vs array scale × FIFO depth, with max/avg/min
/// feature-sparsity bands per model.
pub fn fig14(effort: Effort, seed: u64, scales: &[usize]) -> String {
    fig14_in(effort, seed, scales, &mut Store::in_memory())
}

/// [`fig14`] against an explicit (possibly resumable) store.
pub fn fig14_in(effort: Effort, seed: u64, scales: &[usize], store: &mut Store) -> String {
    let depths = [2usize, 4, 8];
    let subsets = [
        FeatureSubset::MaxSparsity,
        FeatureSubset::Average,
        FeatureSubset::MinSparsity,
    ];
    let squares: Vec<(usize, usize)> = scales.iter().map(|&s| (s, s)).collect();
    let grid = Grid::new(effort, seed)
        .models(&PAPER_MODELS)
        .subsets(&subsets)
        .scales(&squares)
        .fifos(&depths.map(FifoDepths::uniform));
    let res = run_grid(&grid, store);
    let mut t = TextTable::new(
        "Fig. 14 — Speedup vs scale and FIFO depth (bands: max/avg/min sparsity)",
        &["model", "scale", "depth", "max-spars.", "average", "min-spars."],
    );
    for m in PAPER_MODELS {
        for &scale in scales {
            for depth in depths {
                let array =
                    ArrayConfig::new(scale, scale).with_fifo(FifoDepths::uniform(depth));
                let speed = |s: FeatureSubset| {
                    res.get(&Job::subset(m, s, array, true, seed, effort)).speedup
                };
                t.row(vec![
                    m.to_string(),
                    format!("{scale}x{scale}"),
                    format!("({depth},{depth},{depth})"),
                    fx(speed(FeatureSubset::MaxSparsity)),
                    fx(speed(FeatureSubset::Average)),
                    fx(speed(FeatureSubset::MinSparsity)),
                ]);
            }
        }
    }
    t.render()
        + "\nPaper shape: ~3.2x average overall; larger arrays degrade \
           speedup slightly; AlexNet has the widest max/min band (widest \
           density distribution in Fig. 3).\n"
}

/// Fig. 15: on-chip energy breakdown with and without the CE array
/// (16×16, per model).
pub fn fig15(effort: Effort, seed: u64) -> String {
    fig15_in(effort, seed, &mut Store::in_memory())
}

/// [`fig15`] against an explicit (possibly resumable) store.
pub fn fig15_in(effort: Effort, seed: u64, store: &mut Store) -> String {
    let grid = Grid::new(effort, seed).models(&PAPER_MODELS).ce(&[true, false]);
    let res = run_grid(&grid, store);
    let mut t = TextTable::new(
        "Fig. 15 — On-chip energy breakdown (pJ fractions), w/ and w/o CE",
        &["model", "CE", "MAC", "SRAM", "FIFO", "CE-arr", "other", "total (norm.)"],
    );
    for m in PAPER_MODELS {
        let array = ArrayConfig::new(16, 16);
        let job =
            |ce: bool| Job::subset(m, FeatureSubset::Average, array, ce, seed, effort);
        let with = res.get(&job(true));
        let without = res.get(&job(false));
        let wo_total = without.onchip_energy().onchip_total();
        for (tag, rec) in [("w/", with), ("w/o", without)] {
            let e = rec.onchip_energy();
            let tot = e.onchip_total();
            t.row(vec![
                m.to_string(),
                tag.to_string(),
                pct(e.mac_pj / tot),
                pct(e.sram_pj / tot),
                pct(e.fifo_pj / tot),
                pct(e.ce_pj / tot),
                pct(e.other_pj / tot),
                format!("{:.3}", tot / wo_total),
            ]);
        }
    }
    t.render()
        + "\nPaper shape: CE cuts the SRAM (FB) slice substantially; MAC \
           and SRAM dominate; FIFO overhead visible but smaller than the \
           savings.\n"
}

/// Fig. 16: on-chip energy-efficiency improvement vs scale × depth.
pub fn fig16(effort: Effort, seed: u64, scales: &[usize]) -> String {
    fig16_in(effort, seed, scales, &mut Store::in_memory())
}

/// [`fig16`] against an explicit (possibly resumable) store.
pub fn fig16_in(effort: Effort, seed: u64, scales: &[usize], store: &mut Store) -> String {
    let depths = [2usize, 4, 8];
    let res = run_grid(&scale_depth_grid(effort, seed, scales, &depths), store);
    let mut t = TextTable::new(
        "Fig. 16 — On-chip energy-efficiency improvement vs naive",
        &["model", "scale", "(2,2,2)", "(4,4,4)", "(8,8,8)"],
    );
    for m in PAPER_MODELS {
        for &scale in scales {
            let mut row = vec![m.to_string(), format!("{scale}x{scale}")];
            for depth in depths {
                let array =
                    ArrayConfig::new(scale, scale).with_fifo(FifoDepths::uniform(depth));
                let rec = res
                    .get(&Job::subset(m, FeatureSubset::Average, array, true, seed, effort));
                row.push(fx(rec.onchip_ee));
            }
            t.row(row);
        }
    }
    t.render()
        + "\nPaper shape: ~1.8x average, best (~1.9x) at depth (2,2,2); \
           improvement scales well with array size; CE contributes ~1.3x \
           (compare Fig. 15 w/o).\n"
}

/// Fig. 17: area-efficiency improvement vs scale × depth.
pub fn fig17(effort: Effort, seed: u64, scales: &[usize]) -> String {
    fig17_in(effort, seed, scales, &mut Store::in_memory())
}

/// [`fig17`] against an explicit (possibly resumable) store.
pub fn fig17_in(effort: Effort, seed: u64, scales: &[usize], store: &mut Store) -> String {
    let depths = [2usize, 4, 8];
    let res = run_grid(&scale_depth_grid(effort, seed, scales, &depths), store);
    let mut t = TextTable::new(
        "Fig. 17 — Area-efficiency improvement vs naive",
        &["model", "scale", "(2,2,2)", "(4,4,4)", "(8,8,8)", "SCNN A.E."],
    );
    for m in PAPER_MODELS {
        let model = effort.thin(&zoo::by_name(m).expect("paper model"));
        for &scale in scales {
            let mut row = vec![m.to_string(), format!("{scale}x{scale}")];
            for depth in depths {
                let array =
                    ArrayConfig::new(scale, scale).with_fifo(FifoDepths::uniform(depth));
                let rec = res
                    .get(&Job::subset(m, FeatureSubset::Average, array, true, seed, effort));
                row.push(fx(rec.area_eff));
            }
            // SCNN AE vs naive at this workload (area-scaled)
            let sc = scnn::cost(model.total_macs(), model.feature_density, model.weight_density);
            let naive_cycles = model.total_macs() as f64 / 1024.0;
            let sc_speed = naive_cycles / sc.mac_cycles as f64;
            let naive_a = area::naive_area(&ArrayConfig::new(32, 32), 2 << 20);
            row.push(fx(sc_speed * naive_a / area::SCNN_AREA_MM2));
            t.row(row);
        }
    }
    t.render()
        + "\nPaper shape: ~2.9x average, larger for small arrays (SRAM \
           savings dominate) shrinking toward ~1.2x at 128x128; beats \
           SCNN's area efficiency.\n"
}

/// The shared Fig. 16/17 grid: paper models × scales × uniform depths.
/// When both figures render from the same store (`s2engine sweep fig16
/// --out dir` then `fig17 --resume --out dir`), the second is pure
/// lookups.
fn scale_depth_grid(effort: Effort, seed: u64, scales: &[usize], depths: &[usize]) -> Grid {
    let squares: Vec<(usize, usize)> = scales.iter().map(|&s| (s, s)).collect();
    let fifos: Vec<FifoDepths> = depths.iter().map(|&d| FifoDepths::uniform(d)).collect();
    Grid::new(effort, seed)
        .models(&PAPER_MODELS)
        .scales(&squares)
        .fifos(&fifos)
}

/// Is `which` a sweep target [`figure`] can render — a paper figure or
/// the `serving` / `cluster` / `backends` / `pareto` summaries? (The
/// CLI checks this before opening — and possibly truncating — a
/// `--out` store.)
pub fn is_figure(which: &str) -> bool {
    matches!(
        which,
        "fig10"
            | "fig11"
            | "fig12"
            | "fig13"
            | "fig14"
            | "fig15"
            | "fig16"
            | "fig17"
            | "serving"
            | "cluster"
            | "backends"
            | "pareto"
    )
}

/// CLI dispatcher: render a figure sweep against an explicit store.
/// Returns `None` for an unknown figure name. `backend` re-bases the
/// `serving`/`cluster` summaries on another accelerator model
/// ([`crate::backend`]); the figN targets are S²Engine paper
/// reproductions and the `backends`/`pareto` studies sweep every
/// backend themselves (here `pareto` uses its default roster; the CLI
/// routes an explicit `--backend` comma-list straight to
/// [`super::pareto::pareto_in`]), so for those a non-default backend
/// also returns `None` (never silently mislabeled S²-only output) —
/// the CLI rejects the combination up front with a specific message.
/// `requests` overrides the serving protocol's request count for the
/// `serving`/`cluster`/`backends` targets (`0` = the default
/// batch-window protocol); the figN targets don't serve requests and
/// `pareto` fixes its own protocol, so a non-zero count likewise
/// returns `None`.
pub fn figure(
    which: &str,
    effort: Effort,
    seed: u64,
    scales: &[usize],
    backend: crate::backend::BackendKind,
    requests: usize,
    store: &mut Store,
) -> Option<String> {
    if !backend.is_default() && !matches!(which, "serving" | "cluster") {
        return None;
    }
    if requests != 0 && !matches!(which, "serving" | "cluster" | "backends") {
        return None;
    }
    Some(match which {
        "fig10" => fig10_in(effort, seed, store),
        "fig11" => fig11_in(effort, seed, store),
        "fig12" => fig12_in(effort, seed, store),
        "fig13" => fig13_in(effort, seed, store),
        "fig14" => fig14_in(effort, seed, scales, store),
        "fig15" => fig15_in(effort, seed, store),
        "fig16" => fig16_in(effort, seed, scales, store),
        "fig17" => fig17_in(effort, seed, scales, store),
        "serving" => super::serving::serving_in(effort, seed, backend, requests, store),
        "cluster" => super::cluster::cluster_in(effort, seed, backend, requests, store),
        "backends" => super::backends::backends_in(effort, seed, requests, store),
        "pareto" => {
            super::pareto::pareto_in(effort, seed, &super::pareto::PARETO_BACKENDS, store)
        }
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick() {
        let s = fig3(Effort::QUICK, 1);
        assert!(s.contains("alexnet") && s.contains("must-MAC"));
    }

    #[test]
    fn fig13_quick_resnet_lower() {
        let s = fig13(Effort::QUICK, 1);
        assert!(s.contains("resnet50"));
        // (shape assertions live in the integration tests)
    }

    #[test]
    fn fig12_base_ratio_normalizes_to_itself() {
        // the r16=0 jobs are the normalization base; a degenerate grid
        // where the table's first data column divides base by base would
        // be caught here (every row must differ from 1.000 somewhere)
        let s = fig12(Effort::QUICK, 1);
        assert!(s.contains("10.0%"));
        assert!(s.contains("100.0%"));
    }

    #[test]
    fn figure_dispatch_known_and_unknown() {
        use crate::backend::BackendKind;
        let s2 = BackendKind::S2;
        assert!(
            figure("fig9", Effort::QUICK, 1, &[16], s2, 0, &mut Store::in_memory())
                .is_none()
        );
        let s = figure("fig15", Effort::QUICK, 1, &[16], s2, 0, &mut Store::in_memory())
            .unwrap();
        assert!(s.contains("w/o"));
        // non-default backends render only the serving/cluster
        // summaries — a figN request must refuse, not mislabel
        let scnn = BackendKind::Scnn;
        assert!(
            figure("fig15", Effort::QUICK, 1, &[16], scnn, 0, &mut Store::in_memory())
                .is_none()
        );
        // likewise a request-count override: figN targets don't serve
        assert!(
            figure("fig15", Effort::QUICK, 1, &[16], s2, 64, &mut Store::in_memory())
                .is_none()
        );
        // pareto is sweepable but fixes its own roster and protocol:
        // backend/request overrides refuse before touching the store
        assert!(is_figure("pareto"));
        assert!(
            figure("pareto", Effort::QUICK, 1, &[16], scnn, 0, &mut Store::in_memory())
                .is_none()
        );
        assert!(
            figure("pareto", Effort::QUICK, 1, &[16], s2, 64, &mut Store::in_memory())
                .is_none()
        );
    }
}
