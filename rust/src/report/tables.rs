//! Table regeneration: Tables I, II, IV and V of the paper.

use super::{fx, pct, Effort, TextTable};
use crate::baseline::{scnn, sparten};
use crate::config::{ArrayConfig, FifoDepths, SimConfig};
use crate::coordinator::Coordinator;
use crate::energy::area;
use crate::models::zoo;

/// Table I: average accesses per parameter by MACs (conv layers).
pub fn table1() -> String {
    let mut t = TextTable::new(
        "Table I — Average accesses per parameter by MACs",
        &["", "AlexNet", "VGG16", "ResNet50"],
    );
    let models = zoo::paper_models();
    t.row(
        std::iter::once("Total MACs".to_string())
            .chain(models.iter().map(|m| {
                let g = m.total_macs() as f64;
                if g >= 1e9 {
                    format!("{:.2}G", g / 1e9)
                } else {
                    format!("{:.0}M", g / 1e6)
                }
            }))
            .collect(),
    );
    t.row(
        std::iter::once("Parameters".to_string())
            .chain(
                models
                    .iter()
                    .map(|m| format!("{:.2}M", m.total_params() as f64 / 1e6)),
            )
            .collect(),
    );
    t.row(
        std::iter::once("Avg. Usage of Param.".to_string())
            .chain(models.iter().map(|m| format!("{:.0}", m.avg_param_usage())))
            .collect(),
    );
    t.render()
        + "\nPaper (full networks incl. FC): 666M/15.3G/3.86G MACs, \
           2.33M/14.7M/23.5M params, usage 572/2082/336.\n"
}

/// Table II: weight and feature sparsity of the three networks.
pub fn table2(seed: u64) -> String {
    use crate::models::pruning::pruned_weights;
    let mut t = TextTable::new(
        "Table II — Weight and feature sparsity (percentage of zeros)",
        &["", "AlexNet", "VGG16", "ResNet50"],
    );
    let models = zoo::paper_models();
    // measure weight sparsity from actually-pruned tensors
    let mut wrow = vec!["Average Weight Sparsity".to_string()];
    for m in &models {
        let mut zeros = 0u64;
        let mut total = 0u64;
        for l in &m.layers {
            let w = pruned_weights(l, m.weight_density, seed);
            zeros += w.data.iter().filter(|v| **v == 0.0).count() as u64;
            total += w.data.len() as u64;
        }
        wrow.push(pct(zeros as f64 / total as f64));
    }
    t.row(wrow);
    t.row(
        std::iter::once("Average Feature Sparsity".to_string())
            .chain(models.iter().map(|m| pct(1.0 - m.feature_density)))
            .collect(),
    );
    t.render() + "\nPaper: weights 64%/68%/76%, features 61%/72%/66%.\n"
}

/// Table IV: additional cycles of mixed-precision processing vs
/// 8-bit-only, for 3.5% and 5% 16-bit ratios across FIFO depths.
pub fn table4(effort: Effort, seed: u64) -> String {
    let model = zoo::synthetic_alexnet(1.0, 1.0); // dense generated model
    let model = effort.thin(&model);
    let mut t = TextTable::new(
        "Table IV — Extra cycles of mixed precision vs 8-bit-only",
        &["16-bit ratio", "(2,2,2)", "(4,4,4)", "(8,8,8)", "(16,16,16)"],
    );
    for ratio16 in [0.035, 0.05] {
        let mut row = vec![pct(ratio16)];
        for depth in [2usize, 4, 8, 16] {
            let array =
                ArrayConfig::new(16, 16).with_fifo(FifoDepths::uniform(depth));
            let mk = |r16: f64| {
                let mut cfg = SimConfig::new(array).with_samples(effort.tile_samples);
                cfg.seed = seed;
                cfg.ratio16 = r16;
                Coordinator::new(cfg).simulate_model_synthetic(&model, 1.0, 1.0)
            };
            let base = mk(0.0).total_s2_wall();
            let mixed = mk(ratio16).total_s2_wall();
            row.push(pct(mixed / base - 1.0));
        }
        t.row(row);
    }
    t.render()
        + "\nPaper: 3.5% ratio -> 16.3%/9.1%/8.4%/8.2% extra cycles; \
           5% -> 24.1%/13.1%/11.9%/11.7% (vs ~10%/~20% for [37]).\n"
}

/// Table V: comparison among S2Engine (32x32, depths 2/4/8), the naive
/// array, SCNN and SparTen — resources, area and improvement factors.
pub fn table5(effort: Effort, seed: u64) -> String {
    // paper compares on AlexNet + VGG16 (evaluated by all designs)
    let models = [
        effort.thin(&zoo::alexnet()),
        effort.thin(&zoo::vgg16()),
    ];
    let mut t = TextTable::new(
        "Table V — S2Engine (32x32) vs Naive vs SCNN vs SparTen",
        &[
            "metric",
            "S2 depth2",
            "S2 depth4",
            "S2 depth8",
            "Naive",
            "SCNN",
            "SparTen",
        ],
    );

    let mut speedups = Vec::new();
    let mut ee = Vec::new();
    let mut ae = Vec::new();
    for depth in [2usize, 4, 8] {
        let array = ArrayConfig::new(32, 32).with_fifo(FifoDepths::uniform(depth));
        let mut s_sum = 0.0;
        let mut e_sum = 0.0;
        let mut a_sum = 0.0;
        for m in &models {
            let mut cfg = SimConfig::new(array).with_samples(effort.tile_samples);
            cfg.seed = seed;
            let r = Coordinator::new(cfg).simulate_model(m, 0);
            s_sum += r.speedup();
            e_sum += r.onchip_ee_improvement();
            a_sum += r.area_efficiency_improvement();
        }
        speedups.push(s_sum / models.len() as f64);
        ee.push(e_sum / models.len() as f64);
        ae.push(a_sum / models.len() as f64);
    }

    // analytic comparators at the two models' average densities
    let (scnn_speed, scnn_ee) = {
        let mut s = 0.0;
        let mut e = 0.0;
        for m in &models {
            let c = scnn::cost(m.total_macs(), m.feature_density, m.weight_density);
            let dense = scnn::cost(m.total_macs(), 1.0, 1.0);
            s += dense.mac_cycles as f64 / c.mac_cycles as f64 / 1.27; // vs naive-dense
            // published metric: EE vs SCNN's own dense version
            e += dense.energy_per_dense_mac / c.energy_per_dense_mac;
        }
        (s / 2.0, e / 2.0)
    };
    let sparten_speed = {
        let mut s = 0.0;
        for m in &models {
            let c = sparten::cost(m.total_macs(), m.feature_density, m.weight_density);
            let dense_cycles = m.total_macs() / sparten::SPARTEN_MULTIPLIERS;
            s += dense_cycles as f64 / c.mac_cycles as f64 * 0.8; // systolic baseline penalty
        }
        s / 2.0
    };

    let s2_area = |d: usize| {
        area::s2_area(
            &ArrayConfig::new(32, 32).with_fifo(FifoDepths::uniform(d)),
            1 << 20,
        )
    };
    t.row(vec![
        "FIFO cap (KB)".into(),
        format!("{:.0}", FifoDepths::uniform(2).bytes_per_pe() * 1024.0 / 1024.0),
        format!("{:.0}", FifoDepths::uniform(4).bytes_per_pe() * 1024.0 / 1024.0),
        format!("{:.0}", FifoDepths::uniform(8).bytes_per_pe() * 1024.0 / 1024.0),
        "-".into(),
        "32".into(),
        "31".into(),
    ]);
    t.row(vec![
        "Total area (mm^2)".into(),
        format!("{:.2}", s2_area(2)),
        format!("{:.2}", s2_area(4)),
        format!("{:.2}", s2_area(8)),
        format!(
            "{:.2}",
            area::naive_area(&ArrayConfig::new(32, 32), 2 << 20)
        ),
        format!("{:.1} (16nm->14nm)", area::SCNN_AREA_MM2),
        format!("{:.1} (45nm->14nm)", area::SPARTEN_AREA_MM2),
    ]);
    t.row(vec![
        "Speedup".into(),
        fx(speedups[0]),
        fx(speedups[1]),
        fx(speedups[2]),
        "1x".into(),
        fx(scnn_speed),
        fx(sparten_speed),
    ]);
    t.row(vec![
        "E.E. improvement".into(),
        fx(ee[0]),
        fx(ee[1]),
        fx(ee[2]),
        "1x".into(),
        fx(scnn_ee),
        "1.4x/0.5x".into(),
    ]);
    t.row(vec![
        "A.E. improvement".into(),
        fx(ae[0]),
        fx(ae[1]),
        fx(ae[2]),
        "1x".into(),
        "2.20x".into(),
        "-".into(),
    ]);
    t.render()
        + "\nPaper: S2 speedup 2.49/3.05/3.29x, E.E. 2.70/2.66/2.59x, \
           A.E. 3.67/4.23/4.11x; SCNN 2.94x/2.21x/2.20x; SparTen 5.60x.\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_models() {
        let s = table1();
        assert!(s.contains("AlexNet") && s.contains("ResNet50"));
        assert!(s.contains("Avg. Usage of Param."));
    }

    #[test]
    fn table2_sparsity_near_targets() {
        let s = table2(1);
        // AlexNet weight sparsity 64% +- 1
        assert!(s.contains("64.0%") || s.contains("63.") || s.contains("64."));
        assert!(s.contains("Average Feature Sparsity"));
    }

    #[test]
    fn table4_quick_runs() {
        let s = table4(Effort::QUICK, 3);
        assert!(s.contains("3.5%"));
        assert!(s.contains("(16,16,16)"));
    }
}

/// Table III (made quantitative): sparsity-exploitation classes at
/// AlexNet-class densities — which strategies gate, skip, and compress,
/// and what that buys in speed and energy.
pub fn table3() -> String {
    use crate::baseline::gating::{cost, Exploits};
    let m = zoo::alexnet();
    let (df, dw) = (m.feature_density, m.weight_density);
    let dense_macs = m.total_macs();
    let dense = cost(dense_macs, df, dw, Exploits::None);
    let mut t = TextTable::new(
        "Table III (quantitative) — sparsity strategies at AlexNet densities",
        &["design class", "gate", "skip MAC", "skip traffic", "speedup", "E.E. vs dense"],
    );
    let rows: &[(&str, Exploits, &str, &str, &str)] = &[
        ("TPU-class dense", Exploits::None, "-", "-", "-"),
        ("Eyeriss-class", Exploits::GateFeature, "F", "-", "F"),
        ("Cnvlutin-class", Exploits::SkipFeature, "F", "F", "F"),
        ("Cambricon-X-class", Exploits::SkipWeight, "W", "W", "W"),
        ("dual-sparse (S2/SCNN/SparTen)", Exploits::SkipBoth, "F+W", "F+W", "F+W"),
    ];
    for (name, policy, gate, skip, traffic) in rows {
        let c = cost(dense_macs, df, dw, *policy);
        t.row(vec![
            name.to_string(),
            gate.to_string(),
            skip.to_string(),
            traffic.to_string(),
            fx(dense.mac_cycles as f64 / c.mac_cycles as f64),
            fx(dense.energy_per_dense_mac / c.energy_per_dense_mac),
        ]);
    }
    t.render()
        + "\nPaper Table III is qualitative; this quantifies each class at \
           AlexNet's Table II densities. Dual sparsity dominates both axes.\n"
}

/// Section 5.2 buffer-provisioning analysis: which of the 71 layers fit
/// the 2 MB (naive) / 1 MB (S2Engine) budgets.
pub fn fits() -> String {
    use crate::sim::buffer::{fit_report, paper_fit_counts};
    let mut t = TextTable::new(
        "Buffer provisioning — layers fitting 2MB (naive) / 1MB (S2Engine)",
        &["model", "layers", "naive fits @2MB", "S2 fits @1MB", "naive spills"],
    );
    for m in zoo::paper_models() {
        let r = fit_report(&m, 2 << 20, 1 << 20);
        t.row(vec![
            r.model.clone(),
            r.layers_total.to_string(),
            r.naive_fits.to_string(),
            r.s2_fits.to_string(),
            r.naive_spills.join(","),
        ]);
    }
    let (naive, s2, total) = paper_fit_counts();
    t.render()
        + &format!(
            "\nTotals: naive {naive}/{total} (paper: 66/71), \
             S2Engine {s2}/{total} (paper: 68/71).\n"
        )
}
