//! Tail-latency vs provisioned-cost Pareto frontier — the capacity
//! planning question the traffic engine exists to answer: *how many
//! arrays of which design meet a p99 SLO, and what does that
//! provisioning cost?*
//!
//! One [`Grid`] declaration over the `backend` × `arrays` axes for
//! AlexNet at a loaded stochastic serving point (Poisson arrivals at
//! 2 k req/s, 20 ms SLO-aware batching windows, batch 4, overlap 0.6,
//! data-parallel replication, 1024-mul parity at 32×32): every
//! comparator serves the *same* arrival timeline through the *same*
//! SLO-windowed cluster scheduler, so the frontier compares deployable
//! capacity, not per-layer analytic walls.
//!
//! Cost is `arrays × cluster makespan` (array-seconds of provisioned
//! hardware to drain the workload) plus the inter-array link energy of
//! whatever sharding the point used — data-parallel replication moves
//! no feature traffic, so the energy column doubles as a sanity check.
//! The SLO target is the *naive* backend's best achievable p99 across
//! the fleet sizes, which makes every backend's "min arrays at SLO"
//! finite by construction and lets the table answer the headline
//! question directly: the sparse designs hit naive's best tail with a
//! fraction of naive's provisioned cost.

use super::{Effort, TextTable};
use crate::backend::BackendKind;
use crate::cluster::shard::link_pj;
use crate::config::ArrayConfig;
use crate::models::FeatureSubset;
use crate::serve::ArrivalProcess;
use crate::sweep::{Grid, Job, Runner, Store};

/// The compared backends, in reporting order — the roster the frontier
/// table and `benches/traffic_engine.rs` (via [`min_arrays_at_slo`])
/// share. The gating baseline is omitted: it shares naive's dense
/// schedule walls, so its frontier points duplicate naive's.
pub const PARETO_BACKENDS: [BackendKind; 4] = [
    BackendKind::Naive,
    BackendKind::Scnn,
    BackendKind::SparTen,
    BackendKind::S2,
];
/// Fleet sizes swept per backend.
const ARRAYS: [usize; 4] = [1, 2, 4, 8];
/// The fixed serving point (matches the backends head-to-head).
const BATCH: usize = 4;
const OVERLAP: f64 = 0.6;
/// PE-count parity with the 1024-multiplier analytic comparators.
const SCALE: usize = 32;
/// The studied CNN — AlexNet, the paper's primary workload.
const MODEL: &str = "alexnet";
/// Offered load: Poisson arrivals at 2 k requests/s.
const RATE: f64 = 2000.0;
/// Per-request queueing budget for the dynamic batcher (seconds).
const SLO: f64 = 0.02;
/// Closed-loop requests per point — enough windows for the p99 to be a
/// real order statistic at every fleet size.
const REQUESTS: usize = 64;

/// One (backend, fleet size) point of the study.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Point {
    backend: BackendKind,
    arrays: usize,
    /// Cluster p99 latency (seconds).
    p99: f64,
    /// Provisioned cost: arrays × cluster makespan (array-seconds).
    cost: f64,
    /// Inter-array link energy (pJ).
    link_pj: f64,
    /// No same-backend point has both lower-or-equal p99 and cost.
    on_frontier: bool,
    /// Meets the study's SLO target (naive's best p99).
    meets_slo: bool,
    /// Chaos-engine retry count — `None` when the record carries no
    /// chaos metrics (the study's own points are chaos-free, and lines
    /// resumed from pre-chaos stores parse the counters as zeros), in
    /// which case the table renders `n/a` rather than a fake zero.
    chaos_retries: Option<f64>,
}

/// Sweep the grid and score every point. Returns the SLO target and
/// the points in roster × fleet order.
fn survey(
    effort: Effort,
    seed: u64,
    backends: &[BackendKind],
    store: &mut Store,
) -> (f64, Vec<Point>) {
    let grid = Grid::new(effort, seed)
        .models(&[MODEL])
        .scales(&[(SCALE, SCALE)])
        .batches(&[BATCH])
        .overlaps(&[OVERLAP])
        .arrays(&ARRAYS)
        .backends(backends)
        .requests(&[REQUESTS])
        .arrivals(&[ArrivalProcess::Poisson { rate: RATE }])
        .slos(&[SLO]);
    let res = Runner::new().run(&grid.plan(), store);
    let array = ArrayConfig::new(SCALE, SCALE);
    let job = |b: BackendKind, n: usize| {
        Job::subset(MODEL, FeatureSubset::Average, array, true, seed, effort)
            .with_batch(BATCH)
            .with_overlap(OVERLAP)
            .with_arrays(n)
            .with_backend(b)
            .with_requests(REQUESTS)
            .with_arrival(ArrivalProcess::Poisson { rate: RATE })
            .with_slo(SLO)
    };
    let best_p99 = |b: BackendKind| {
        ARRAYS
            .iter()
            .map(|&n| res.get(&job(b, n)).cluster_p99_latency)
            .fold(f64::INFINITY, f64::min)
    };
    // the target every design must hit: the dense baseline's best tail.
    // Without naive in the roster, fall back to the worst per-backend
    // best — either way every swept backend meets it somewhere.
    let target = if backends.contains(&BackendKind::Naive) {
        best_p99(BackendKind::Naive)
    } else {
        backends.iter().map(|&b| best_p99(b)).fold(0.0, f64::max)
    };
    let mut points = Vec::new();
    for &b in backends {
        let raw: Vec<(usize, f64, f64, f64, Option<f64>)> = ARRAYS
            .iter()
            .map(|&n| {
                let rec = res.get(&job(b, n));
                (
                    n,
                    rec.cluster_p99_latency,
                    n as f64 * rec.cluster_makespan,
                    link_pj(rec.link_bytes),
                    rec.has_chaos_metrics().then_some(rec.chaos_retries),
                )
            })
            .collect();
        for &(n, p99, cost, link, chaos_retries) in &raw {
            let dominated = raw.iter().any(|&(m, q, c, _, _)| {
                m != n && q <= p99 && c <= cost && (q < p99 || c < cost)
            });
            points.push(Point {
                backend: b,
                arrays: n,
                p99,
                cost,
                link_pj: link,
                on_frontier: !dominated,
                meets_slo: p99 <= target,
                chaos_retries,
            });
        }
    }
    (target, points)
}

/// Pareto frontier study with a throwaway in-memory store.
pub fn pareto(effort: Effort, seed: u64, backends: &[BackendKind]) -> String {
    pareto_in(effort, seed, backends, &mut Store::in_memory())
}

/// [`pareto`] against an explicit (possibly resumable) store.
pub fn pareto_in(
    effort: Effort,
    seed: u64,
    backends: &[BackendKind],
    store: &mut Store,
) -> String {
    let (target, points) = survey(effort, seed, backends, store);
    let mut t = TextTable::new(
        format!(
            "Pareto — tail latency vs provisioned cost (alexnet, 32x32 / \
             1024 muls, poisson {RATE:.0} req/s, slo {:.0} ms, batch {BATCH}, \
             overlap {OVERLAP}, data-parallel, {REQUESTS} requests)",
            SLO * 1e3
        ),
        &[
            "backend", "arrays", "p99 (ms)", "cost (array*ms)", "link (pJ)",
            "frontier", "meets slo", "retries",
        ],
    );
    for p in &points {
        t.row(vec![
            p.backend.tag().to_string(),
            format!("{}", p.arrays),
            format!("{:.3}", p.p99 * 1e3),
            format!("{:.3}", p.cost * 1e3),
            format!("{:.1}", p.link_pj),
            if p.on_frontier { "*".to_string() } else { String::new() },
            if p.meets_slo { "yes".to_string() } else { String::new() },
            // chaos-free points (and pre-chaos store lines) carry no
            // chaos metrics — n/a, never a fabricated zero
            match p.chaos_retries {
                Some(r) => format!("{r:.0}"),
                None => "n/a".into(),
            },
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\nSLO target: {:.3} ms p99 (the dense baseline's best achievable \
         tail across fleet sizes). Min arrays to meet it:\n",
        target * 1e3
    ));
    for &b in backends {
        let min = points
            .iter()
            .filter(|p| p.backend == b && p.meets_slo)
            .map(|p| p.arrays)
            .min();
        match min {
            Some(n) => out.push_str(&format!("  {:>8}  {n} arrays\n", b.tag())),
            None => out.push_str(&format!("  {:>8}  not met\n", b.tag())),
        }
    }
    out.push_str(
        "Reading: `*` marks each backend's own (p99, cost) frontier; cost is \
         arrays x cluster makespan — the array-seconds provisioned to drain \
         the Poisson workload under SLO-aware batching. The sparse designs \
         reach the dense baseline's best tail latency with a fraction of its \
         provisioned cost; data-parallel replication moves no inter-array \
         feature traffic, so link energy stays zero on this frontier.\n",
    );
    out
}

/// Smallest data-parallel fleet at which S²Engine meets the study's
/// SLO target — the headline scalar `benches/traffic_engine.rs`
/// publishes (`pareto/min-arrays-at-slo`). Panics if no swept fleet
/// size meets it, which the target's construction rules out.
pub fn min_arrays_at_slo(effort: Effort, seed: u64) -> usize {
    let (_, points) = survey(effort, seed, &PARETO_BACKENDS, &mut Store::in_memory());
    points
        .iter()
        .filter(|p| p.backend == BackendKind::S2 && p.meets_slo)
        .map(|p| p.arrays)
        .min()
        .expect("S2 meets the naive-derived SLO target at some fleet size")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Effort {
        Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        }
    }

    #[test]
    fn frontier_dominates_naive_at_every_fleet_size() {
        let (target, points) =
            survey(tiny(), 0xc0de_cafe_0080, &PARETO_BACKENDS, &mut Store::in_memory());
        assert!(target.is_finite() && target > 0.0);
        assert_eq!(points.len(), PARETO_BACKENDS.len() * ARRAYS.len());
        let at = |b: BackendKind, n: usize| {
            *points
                .iter()
                .find(|p| p.backend == b && p.arrays == n)
                .unwrap()
        };
        for &n in &ARRAYS {
            let naive = at(BackendKind::Naive, n);
            assert!(naive.p99 > 0.0 && naive.cost > 0.0);
            // at every fleet size some sparse design strictly dominates
            // the dense baseline on both axes
            let dominated = PARETO_BACKENDS.iter().any(|&b| {
                b != BackendKind::Naive && {
                    let p = at(b, n);
                    p.p99 < naive.p99 && p.cost < naive.cost
                }
            });
            assert!(dominated, "naive undominated at {n} arrays");
            // data-parallel replication moves no feature bytes
            for &b in &PARETO_BACKENDS {
                assert_eq!(at(b, n).link_pj, 0.0);
                // the study is chaos-free: no point fabricates chaos
                // counters
                assert_eq!(at(b, n).chaos_retries, None);
            }
        }
        // every backend meets the naive-derived target somewhere, and
        // every backend has at least one frontier point
        for &b in &PARETO_BACKENDS {
            assert!(points.iter().any(|p| p.backend == b && p.meets_slo));
            assert!(points.iter().any(|p| p.backend == b && p.on_frontier));
        }
        // S2 needs no more provisioned arrays than the dense baseline
        let min = |b: BackendKind| {
            points
                .iter()
                .filter(|p| p.backend == b && p.meets_slo)
                .map(|p| p.arrays)
                .min()
                .unwrap()
        };
        assert!(min(BackendKind::S2) <= min(BackendKind::Naive));
    }

    #[test]
    fn pareto_renders_and_is_store_resumable() {
        let effort = tiny();
        let seed = 0xc0de_cafe_0081;
        let mut store = Store::in_memory();
        let first = pareto_in(effort, seed, &PARETO_BACKENDS, &mut store);
        assert_eq!(store.len(), PARETO_BACKENDS.len() * ARRAYS.len());
        for b in PARETO_BACKENDS {
            assert!(first.contains(b.tag()), "missing {} in:\n{first}", b.tag());
        }
        assert!(first.contains('*'), "no frontier points marked:\n{first}");
        assert!(first.contains("SLO target"), "no target line:\n{first}");
        // the chaos-free study renders n/a retries, not fake zeros
        assert!(first.contains("n/a"), "chaos column not n/a:\n{first}");
        // a warm store reuses every point and renders byte-identically
        let second = pareto_in(effort, seed, &PARETO_BACKENDS, &mut store);
        assert_eq!(first, second);
    }

    #[test]
    fn min_arrays_at_slo_lies_in_the_swept_fleet() {
        let n = min_arrays_at_slo(tiny(), 0xc0de_cafe_0082);
        assert!(ARRAYS.contains(&n), "min arrays {n} not a swept size");
    }
}
