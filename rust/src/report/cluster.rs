//! Cluster scale-out summary — the fleet-level companion to the
//! serving report.
//!
//! One [`Grid`] declaration over the `arrays` × `shard` cluster axes
//! for the three evaluated CNNs at a fixed serving point (batch 4,
//! overlap 0.6); each point reports the scale-out metrics
//! ([`crate::cluster`]): cluster throughput, tail latency, mean
//! per-array occupancy, link traffic, and scale-out efficiency
//! `T₁ / (N × T_N)`. Like every figure sweep, the summary renders from
//! [`crate::sweep::SweepResults`] and therefore inherits job sharding,
//! tile-memo reuse and `--resume`-able stores
//! (`s2engine sweep cluster --out DIR --resume`).

use super::{Effort, TextTable};
use crate::backend::BackendKind;
use crate::cluster::ShardStrategy;
use crate::config::ArrayConfig;
use crate::models::FeatureSubset;
use crate::sweep::{Grid, Job, Runner, Store};

/// The three CNNs the paper evaluates, in reporting order.
const PAPER_MODELS: [&str; 3] = ["alexnet", "vgg16", "resnet50"];
/// The event-driven workloads of the second section: the spiking model
/// (timestep-decayed density) and the residual skip-connection DAG.
const EVENT_MODELS: [&str; 2] = ["snn", "resnet8"];
/// Cluster sizes the summary sweeps.
const ARRAYS: [usize; 4] = [1, 2, 4, 8];
/// Cluster sizes of the event-workload section (kept small: the point
/// is shard-strategy coverage of the branchy DAG, not a scaling curve).
const EVENT_ARRAYS: [usize; 2] = [1, 4];
/// The fixed serving point (batching + overlap make the per-array
/// pipelines representative of a loaded deployment).
const BATCH: usize = 4;
const OVERLAP: f64 = 0.6;

/// Cluster summary with a throwaway in-memory store. `backend` selects
/// the accelerator model being scaled out ([`crate::backend`]):
/// `s2engine sweep cluster --backend sparten` renders the same
/// scale-out study for a SparTen fleet. `requests` overrides the
/// closed-loop request count per point (`0` = the default
/// `batch × SERVE_WINDOWS` protocol) — the high-R regime the scheduler
/// fast path unlocks.
pub fn cluster(effort: Effort, seed: u64, backend: BackendKind, requests: usize) -> String {
    cluster_in(effort, seed, backend, requests, &mut Store::in_memory())
}

/// [`cluster`] against an explicit (possibly resumable) store.
pub fn cluster_in(
    effort: Effort,
    seed: u64,
    backend: BackendKind,
    requests: usize,
    store: &mut Store,
) -> String {
    // the analytic comparators model 1024-multiplier machines;
    // evaluate them at PE parity (Table V's normalization) instead of
    // the S² default 16x16 working point
    let scale = backend.parity_scale().unwrap_or(16);
    let grid = Grid::new(effort, seed)
        .models(&PAPER_MODELS)
        .scales(&[(scale, scale)])
        .batches(&[BATCH])
        .overlaps(&[OVERLAP])
        .arrays(&ARRAYS)
        .shards(&ShardStrategy::ALL)
        .backends(&[backend])
        .requests(&[requests]);
    let res = Runner::new().run(&grid.plan(), store);
    let protocol = if requests == 0 {
        String::new()
    } else {
        format!(", {requests} requests")
    };
    let mut t = TextTable::new(
        format!(
            "Cluster — scale-out serving across N arrays ({scale}x{scale}, \
             avg subset, batch 4, overlap 0.6, backend {}{protocol})",
            backend.tag()
        ),
        &[
            "model", "arrays", "shard", "img/s", "p99 lat", "occupancy",
            "link MB", "scale-out eff",
        ],
    );
    let array = ArrayConfig::new(scale, scale);
    let job = |m: &str, n: usize, s: ShardStrategy| {
        Job::subset(m, FeatureSubset::Average, array, true, seed, effort)
            .with_batch(BATCH)
            .with_overlap(OVERLAP)
            .with_arrays(n)
            .with_shard(s)
            .with_backend(backend)
            .with_requests(requests)
    };
    // records recovered from a store written before the cluster axes
    // existed carry no cluster metrics — render "n/a", never zeros
    let mut any_legacy = false;
    for m in PAPER_MODELS {
        for n in ARRAYS {
            for s in ShardStrategy::ALL {
                let rec = res.get(&job(m, n, s));
                let ok = rec.has_cluster_metrics();
                any_legacy |= !ok;
                let cell = |v: String| if ok { v } else { "n/a".to_string() };
                // cluster throughput reconstructed from the stored
                // efficiency: requests/T_N = (requests/T₁) × N × eff,
                // and `throughput` is exactly requests/T₁ (the serving
                // run shares the schedule arithmetic bit-for-bit)
                t.row(vec![
                    m.to_string(),
                    n.to_string(),
                    s.tag().to_string(),
                    cell(format!("{:.1}", rec.throughput * rec.scaleout_eff * n as f64)),
                    cell(format!("{:.3} ms", rec.cluster_p99_latency * 1e3)),
                    cell(format!("{:.2}", rec.cluster_occupancy)),
                    cell(format!("{:.2}", rec.link_bytes / 1e6)),
                    cell(format!("{:.2}", rec.scaleout_eff)),
                ]);
            }
        }
    }
    let mut out = t.render()
        + "\nReading: arrays=1 is the single-array pipeline (eff 1.00 for \
           every strategy, by construction). Data-parallel replication \
           scales closed-loop throughput near-linearly with zero link \
           traffic; layer-pipeline trades occupancy balance for stage \
           transfers; tensor sharding shrinks per-array compute but pays \
           an all-gather per layer.\n";
    if any_legacy {
        out.push_str(
            "n/a: point recovered from a pre-cluster store (no cluster \
             metrics recorded); rerun into a fresh --out to measure it.\n",
        );
    }
    out.push('\n');
    out.push_str(&event_section(effort, seed, backend, requests, store));
    out
}

/// The second table: event workloads (spiking + residual DAG) scaled
/// out under every shard strategy. At full effort (`--effort full`,
/// layer stride 1) `resnet8` keeps its skip edges, so the pipeline and
/// tensor shards schedule a genuinely branchy precedence graph.
fn event_section(
    effort: Effort,
    seed: u64,
    backend: BackendKind,
    requests: usize,
    store: &mut Store,
) -> String {
    let scale = backend.parity_scale().unwrap_or(16);
    let grid = Grid::new(effort, seed)
        .models(&EVENT_MODELS)
        .scales(&[(scale, scale)])
        .batches(&[BATCH])
        .overlaps(&[OVERLAP])
        .arrays(&EVENT_ARRAYS)
        .shards(&ShardStrategy::ALL)
        .backends(&[backend])
        .requests(&[requests]);
    let res = Runner::new().run(&grid.plan(), store);
    let mut t = TextTable::new(
        format!(
            "Cluster — event workloads across N arrays ({scale}x{scale}, \
             batch {BATCH}, overlap {OVERLAP}, backend {})",
            backend.tag()
        ),
        &[
            "model", "arrays", "shard", "img/s", "p99 lat", "occupancy",
            "link MB", "scale-out eff",
        ],
    );
    let array = ArrayConfig::new(scale, scale);
    for m in EVENT_MODELS {
        for n in EVENT_ARRAYS {
            for s in ShardStrategy::ALL {
                let job = Job::subset(m, FeatureSubset::Average, array, true, seed, effort)
                    .with_batch(BATCH)
                    .with_overlap(OVERLAP)
                    .with_arrays(n)
                    .with_shard(s)
                    .with_backend(backend)
                    .with_requests(requests);
                let rec = res.get(&job);
                let ok = rec.has_cluster_metrics();
                let cell = |v: String| if ok { v } else { "n/a".to_string() };
                t.row(vec![
                    m.to_string(),
                    n.to_string(),
                    s.tag().to_string(),
                    cell(format!("{:.1}", rec.throughput * rec.scaleout_eff * n as f64)),
                    cell(format!("{:.3} ms", rec.cluster_p99_latency * 1e3)),
                    cell(format!("{:.2}", rec.cluster_occupancy)),
                    cell(format!("{:.2}", rec.link_bytes / 1e6)),
                    cell(format!("{:.2}", rec.scaleout_eff)),
                ]);
            }
        }
    }
    t.render()
        + "\nReading: `snn` serves one inference as 4 timestep passes at \
           decaying spike density; `resnet8` carries skip-connection \
           precedence edges (kept at layer stride 1, i.e. --effort full).\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Effort {
        Effort {
            tile_samples: 1,
            layer_stride: 8,
            images: 0,
        }
    }

    #[test]
    fn cluster_summary_covers_models_arrays_and_strategies() {
        let s = cluster(tiny(), 0xc0de_cafe_0040, BackendKind::S2, 0);
        for m in PAPER_MODELS {
            assert!(s.contains(m), "missing {m} in:\n{s}");
        }
        for tag in ["data", "pipeline", "tensor"] {
            assert!(s.contains(tag), "missing {tag} in:\n{s}");
        }
        assert!(s.contains("scale-out eff"));
        assert!(s.contains("1.00"), "single-array efficiency row present");
        assert!(!s.contains("n/a"), "fresh run has no legacy points:\n{s}");
    }

    #[test]
    fn event_section_covers_models_and_strategies() {
        let s = cluster(tiny(), 0xc0de_cafe_0044, BackendKind::S2, 0);
        assert!(s.contains("event workloads"), "second section present:\n{s}");
        for m in EVENT_MODELS {
            assert!(s.contains(m), "missing {m} in:\n{s}");
        }
        assert!(!s.contains("n/a"), "fresh run measures every point:\n{s}");
    }

    #[test]
    fn cluster_summary_runs_under_an_analytic_backend() {
        let s = cluster(tiny(), 0xc0de_cafe_0042, BackendKind::SparTen, 0);
        assert!(s.contains("backend sparten"), "title names the backend:\n{s}");
        assert!(s.contains("1.00"), "single-array efficiency row present");
        assert!(!s.contains("n/a"), "analytic run measures every point:\n{s}");
    }

    #[test]
    fn cluster_summary_accepts_request_override() {
        let s = cluster(tiny(), 0xc0de_cafe_0043, BackendKind::S2, 96);
        assert!(s.contains("96 requests"), "title names the protocol:\n{s}");
        assert!(!s.contains("n/a"), "override points all measured:\n{s}");
    }

    #[test]
    fn legacy_store_records_render_na() {
        // a record recovered from a pre-cluster store (cluster metrics
        // parsed as zeros) must render as n/a, not as measured zeros
        let effort = tiny();
        let seed = 0xc0de_cafe_0041;
        let mut warm = Store::in_memory();
        let _ = cluster_in(effort, seed, BackendKind::S2, 0, &mut warm);
        let base = Job::subset(
            "alexnet",
            FeatureSubset::Average,
            ArrayConfig::new(16, 16),
            true,
            seed,
            effort,
        )
        .with_batch(BATCH)
        .with_overlap(OVERLAP);
        let mut legacy = warm
            .get(base.key())
            .expect("single-array point simulated")
            .clone();
        legacy.cluster_occupancy = 0.0;
        legacy.link_bytes = 0.0;
        legacy.cluster_p99_latency = 0.0;
        legacy.scaleout_eff = 0.0;
        assert!(!legacy.has_cluster_metrics());
        let mut store = Store::in_memory();
        store.admit(legacy);
        let s = cluster_in(effort, seed, BackendKind::S2, 0, &mut store);
        assert!(s.contains("n/a"), "legacy point must render n/a:\n{s}");
        assert!(s.contains("pre-cluster store"), "footnote expected");
    }
}
