//! Regeneration of every table and figure in the paper's evaluation
//! (Section 6). Each function runs the required simulations and returns a
//! text rendering (the same rows/series the paper plots); the benches and
//! the CLI (`s2engine report ...` / `s2engine sweep ...`) call these.
//!
//! The simulation-backed figure sweeps (Figs. 10–17) are thin
//! declarations over the [`crate::sweep`] engine: each figure states a
//! [`crate::sweep::Grid`] and renders the returned records, so they
//! inherit job sharding, tile-memo reuse, and `--resume`-able stores
//! for free. The analytic tables (I–V) remain direct computations.
//!
//! Effort control: the full paper evaluation is hours of simulation; the
//! [`Effort`] knob trades tile-sample count and layer coverage for
//! wall-time while preserving the reported ratios (tiles and layers are
//! sampled deterministically).

pub mod backends;
pub mod cluster;
pub mod figures;
pub mod pareto;
pub mod serving;
pub mod tables;

pub use backends::{backends, backends_in};
pub use cluster::{cluster, cluster_in};
pub use figures::*;
pub use pareto::{min_arrays_at_slo, pareto, pareto_in};
pub use serving::{serving, serving_in};
pub use tables::*;

use crate::models::Model;

/// Simulation effort for report generation.
#[derive(Debug, Clone, Copy)]
pub struct Effort {
    /// Tiles sampled per layer (0 = every tile).
    pub tile_samples: usize,
    /// Keep every `layer_stride`-th layer of each model (1 = all).
    pub layer_stride: usize,
    /// Images sampled for distribution plots.
    pub images: usize,
}

impl Effort {
    /// Quick smoke effort: seconds per figure.
    pub const QUICK: Effort = Effort {
        tile_samples: 2,
        layer_stride: 4,
        images: 500,
    };
    /// Default effort: tens of seconds per figure.
    pub const DEFAULT: Effort = Effort {
        tile_samples: 6,
        layer_stride: 2,
        images: 2000,
    };
    /// Full effort (paper-grade averaging).
    pub const FULL: Effort = Effort {
        tile_samples: 16,
        layer_stride: 1,
        images: 10000,
    };

    pub fn from_name(name: &str) -> Effort {
        match name {
            "quick" => Effort::QUICK,
            "full" => Effort::FULL,
            _ => Effort::DEFAULT,
        }
    }

    /// Thin a model's layer list by the stride (always keeps the first
    /// and last layers — they bound the shape spectrum). A thinned model
    /// loses its skip edges (`deps` indices would dangle) and schedules
    /// as a chain of the surviving layers; `density_scale` is subset by
    /// the same filter so each kept layer keeps its own multiplier.
    pub fn thin(&self, model: &Model) -> Model {
        if self.layer_stride <= 1 || model.layers.len() <= 2 {
            return model.clone();
        }
        let mut m = model.clone();
        let last = model.layers.len() - 1;
        let keep = |i: usize| i == 0 || i == last || i % self.layer_stride == 0;
        m.layers = model
            .layers
            .iter()
            .enumerate()
            .filter(|(i, _)| keep(*i))
            .map(|(_, l)| l.clone())
            .collect();
        m.deps = None;
        if !model.density_scale.is_empty() {
            m.density_scale = model
                .density_scale
                .iter()
                .enumerate()
                .filter(|(i, _)| keep(*i))
                .map(|(_, s)| *s)
                .collect();
        }
        m
    }
}

/// Plain-text table builder (fixed-width columns).
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl TextTable {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.into(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | ", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push_str(&format!(
            "|{}\n",
            widths
                .iter()
                .map(|w| format!("{}-|", "-".repeat(w + 2)))
                .collect::<String>()
                .trim_end_matches('|')
        ));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

/// Format helper: `3.14x`.
pub fn fx(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format helper: percent.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn thin_keeps_first_and_last() {
        let m = zoo::resnet50();
        let t = Effort::QUICK.thin(&m);
        assert!(t.layers.len() < m.layers.len());
        assert_eq!(t.layers[0].name, m.layers[0].name);
        assert_eq!(
            t.layers.last().unwrap().name,
            m.layers.last().unwrap().name
        );
    }

    #[test]
    fn thin_stride_one_is_identity() {
        let m = zoo::vgg16();
        let t = Effort::FULL.thin(&m);
        assert_eq!(t.layers.len(), m.layers.len());
        // identity path keeps deps and density_scale untouched
        let r = Effort::FULL.thin(&zoo::resnet8());
        assert!(r.deps.is_some());
        let s = Effort::FULL.thin(&zoo::snn());
        assert_eq!(s.density_scale, zoo::snn().density_scale);
    }

    #[test]
    fn thin_drops_deps_and_subsets_density_scale() {
        // actually-thinned models fall back to chain scheduling and keep
        // each surviving layer's own density multiplier
        let r = Effort::QUICK.thin(&zoo::resnet8());
        assert!(r.layers.len() < 8);
        assert!(r.deps.is_none());
        let s = Effort::QUICK.thin(&zoo::snn());
        assert_eq!(s.density_scale.len(), s.layers.len());
        let m = zoo::snn();
        let last = m.layers.len() - 1;
        let expect: Vec<f64> = m
            .density_scale
            .iter()
            .enumerate()
            .filter(|(i, _)| *i == 0 || *i == last || i % Effort::QUICK.layer_stride == 0)
            .map(|(_, v)| *v)
            .collect();
        assert_eq!(s.density_scale, expect);
    }

    #[test]
    fn text_table_renders() {
        let mut t = TextTable::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| a "));
        assert!(s.contains("| 1 "));
    }

    #[test]
    #[should_panic]
    fn text_table_checks_columns() {
        let mut t = TextTable::new("Demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn effort_lookup() {
        assert_eq!(Effort::from_name("quick").tile_samples, 2);
        assert_eq!(Effort::from_name("full").layer_stride, 1);
        assert_eq!(Effort::from_name("whatever").tile_samples, 6);
    }
}
