//! Analytic SCNN comparator (Parashar et al., ISCA 2017).
//!
//! The paper compares S²Engine against SCNN's *published* numbers rather
//! than re-implementing it (Table V, Fig. 11/17); we do the same with an
//! analytic model calibrated to the characteristics SCNN reports:
//!
//! * 1024 multipliers organised as 64 PEs × (4×4) Cartesian-product
//!   F/I multiplier arrays;
//! * multiplier under-utilisation at low density (a 4-wide F or I vector
//!   cannot be filled when too few non-zeros remain in a stripe) and at
//!   the edges of small channel tiles;
//! * crossbar/accumulator-bank contention: SCNN reports ~79% of the
//!   speed of an equivalent dense accelerator on *dense* networks and a
//!   ~1.33× energy overhead there (Section 3.2 of the S²Engine paper);
//! * coordinate-transformation energy on every product.

use crate::models::Model;

/// SCNN machine constants (from the SCNN paper's 1024-multiplier config).
pub const SCNN_MULTIPLIERS: u64 = 1024;
/// Speed fraction on dense workloads vs an ideal dense accelerator.
pub const DENSE_SPEED_FACTOR: f64 = 0.79;
/// Energy overhead factor on dense workloads.
pub const DENSE_ENERGY_OVERHEAD: f64 = 1.33;
/// Density-independent energy share (crossbar, accumulator buffers,
/// coordinate pipeline — the structures that do not scale away with
/// sparsity). Calibrated so SCNN's sparse-vs-dense energy-efficiency
/// improvement on AlexNet/VGG-class sparsity reproduces its published
/// ~2.21x (Table V): e(df,dw) = FIXED + (1.33 - FIXED)*df*dw.
pub const FIXED_ENERGY: f64 = 0.506;

/// Analytic cost of running a workload with `dense_macs` total MACs at
/// the given feature/weight densities.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScnnCost {
    pub mac_cycles: u64,
    pub mac_ops: u64,
    /// Relative on-chip energy per dense-MAC-equivalent, normalized so a
    /// dense ideal accelerator is 1.0 (used by Fig. 11's energy panel).
    pub energy_per_dense_mac: f64,
}

impl ScnnCost {
    pub fn wall_seconds(&self) -> f64 {
        super::wall_seconds(self.mac_cycles)
    }
}

/// Multiplier-array utilisation as a function of operand density: each
/// cycle a PE crosses a 4-vector of non-zero features with a 4-vector of
/// non-zero weights; gathering 4 non-zeros from a sparse stripe leaves
/// bubbles when fewer remain (tail fragmentation). The fragmentation
/// model: utilisation of a d-dense stream gathered in chunks of 4 from
/// 16-element stripes ≈ E[ceil(16d)/4·4-slots filled] — approximated
/// smoothly; multiplied by the crossbar contention ceiling.
pub fn utilization(df: f64, dw: f64) -> f64 {
    let frag = |d: f64| {
        let nz = (16.0 * d).max(1e-9);
        // slots used = ceil(nz/4)*4 -> efficiency nz / that
        let slots = (nz / 4.0).ceil() * 4.0;
        nz / slots
    };
    DENSE_SPEED_FACTOR * frag(df) * frag(dw)
}

/// Cost for `dense_macs` at densities (df, dw).
pub fn cost(dense_macs: u64, df: f64, dw: f64) -> ScnnCost {
    let must = (dense_macs as f64 * df * dw).ceil();
    let util = utilization(df, dw);
    let mac_cycles = (must / (SCNN_MULTIPLIERS as f64 * util)).ceil() as u64;
    // energy: a fixed share (crossbar / accumulator banks / coordinate
    // pipeline) plus a product-scaled compute share; normalized so the
    // dense point is the published 1.33x overhead.
    let energy = FIXED_ENERGY + (DENSE_ENERGY_OVERHEAD - FIXED_ENERGY) * df * dw;
    ScnnCost {
        mac_cycles,
        mac_ops: must as u64,
        energy_per_dense_mac: energy,
    }
}

/// Cost over a whole model at its Table II densities.
pub fn model_cost(model: &Model) -> ScnnCost {
    let dense = model.total_macs();
    cost(dense, model.feature_density, model.weight_density)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_runs_at_79_percent() {
        let c = cost(1_000_000, 1.0, 1.0);
        let ideal_cycles = 1_000_000 / SCNN_MULTIPLIERS;
        let ratio = ideal_cycles as f64 / c.mac_cycles as f64;
        assert!((ratio - 0.79).abs() < 0.02, "dense speed factor {ratio}");
    }

    #[test]
    fn dense_energy_overhead() {
        let c = cost(1_000_000, 1.0, 1.0);
        assert!((c.energy_per_dense_mac - 1.33).abs() < 1e-9);
    }

    #[test]
    fn published_sparse_ee_improvement() {
        // AlexNet/VGG-class sparsity: EE vs SCNN's own dense version
        // must land near the published 2.21x.
        let dense = cost(1_000_000, 1.0, 1.0);
        let sparse = cost(1_000_000, 0.38, 0.30);
        let ee = dense.energy_per_dense_mac / sparse.energy_per_dense_mac;
        assert!((ee - 2.21).abs() < 0.25, "EE {ee}");
    }

    #[test]
    fn sparse_is_faster_than_dense() {
        let sparse = cost(1_000_000, 0.4, 0.35);
        let dense = cost(1_000_000, 1.0, 1.0);
        assert!(sparse.mac_cycles * 3 < dense.mac_cycles);
    }

    #[test]
    fn very_low_density_fragmentation_hurts() {
        // utilization at 10% density is much worse than at 50%
        assert!(utilization(0.1, 0.1) < utilization(0.5, 0.5) * 0.6);
    }

    #[test]
    fn must_macs_scale_with_density_product() {
        let c = cost(1_000_000, 0.5, 0.4);
        assert_eq!(c.mac_ops, 200_000);
    }
}
