//! Partial-sparsity comparators — the Table III design space.
//!
//! The paper's Table III classifies accelerators by which operand's
//! sparsity they exploit and at which level (gate the MAC, skip the MAC
//! cycle, skip the buffer/DRAM access). This module provides analytic
//! models for the two canonical partial designs so the Table III
//! comparison can be made *quantitative* (report::table3):
//!
//! * **Cnvlutin-class** (feature sparsity only, [15]): skips MAC cycles
//!   and buffer accesses for zero *features*; zero weights still occupy
//!   cycles.
//! * **Cambricon-X-class** (weight sparsity only, [14]): the dual.
//! * **Eyeriss-class** (feature gating only, [31]): *gates* zero-feature
//!   MACs (saves energy) but cannot skip the cycle — no speedup.
//!
//! All are normalized to the same 1024-multiplier dense baseline used by
//! the SCNN/SparTen models.

pub const MULTIPLIERS: u64 = 1024;

/// Which operand's sparsity a design exploits for cycle skipping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Exploits {
    /// Gate only (energy, no cycles): Eyeriss-class.
    GateFeature,
    /// Skip cycles on zero features: Cnvlutin-class.
    SkipFeature,
    /// Skip cycles on zero weights: Cambricon-X-class.
    SkipWeight,
    /// Skip on both: SCNN/SparTen/S2Engine-class (for reference rows).
    SkipBoth,
    /// Nothing: TPU-class dense.
    None,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GatingCost {
    pub mac_cycles: u64,
    /// MACs actually performed (gated/skipped work excluded).
    pub mac_ops: u64,
    /// Energy per dense-MAC-equivalent, dense ideal = 1.0.
    pub energy_per_dense_mac: f64,
}

impl GatingCost {
    pub fn wall_seconds(&self) -> f64 {
        super::wall_seconds(self.mac_cycles)
    }
}

/// Analytic cost under a partial-exploitation policy. `overhead` models
/// the indexing/select logic of the design class (Cnvlutin's offset
/// lanes, Cambricon-X's indexing module) as a multiplicative energy term
/// on performed work.
pub fn cost(dense_macs: u64, df: f64, dw: f64, policy: Exploits) -> GatingCost {
    let (cycle_fraction, gated_fraction, overhead) = match policy {
        Exploits::None => (1.0, 1.0, 1.0),
        Exploits::GateFeature => (1.0, df, 1.02),
        Exploits::SkipFeature => (df, df, 1.10),
        Exploits::SkipWeight => (dw, dw, 1.12),
        Exploits::SkipBoth => (df * dw, df * dw, 1.18),
    };
    let mac_cycles = ((dense_macs as f64 * cycle_fraction)
        / MULTIPLIERS as f64)
        .ceil()
        .max(1.0) as u64;
    // energy: performed MACs (gated ones cost ~nothing) + a traffic term
    // that scales with what the design can compress away
    let traffic = match policy {
        Exploits::None => 0.35,
        Exploits::GateFeature => 0.30,
        Exploits::SkipFeature => 0.35 * (df + 1.0) / 2.0,
        Exploits::SkipWeight => 0.35 * (dw + 1.0) / 2.0,
        Exploits::SkipBoth => 0.35 * (df + dw) / 2.0,
    };
    GatingCost {
        mac_cycles,
        mac_ops: (dense_macs as f64 * gated_fraction).ceil() as u64,
        energy_per_dense_mac: gated_fraction * 0.65 * overhead + traffic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DF: f64 = 0.39;
    const DW: f64 = 0.36;
    const M: u64 = 1_000_000_000;

    #[test]
    fn speedup_ordering_matches_table3() {
        // skip-both > skip-one > gate-only == dense on speed
        let dense = cost(M, DF, DW, Exploits::None).mac_cycles;
        let gate = cost(M, DF, DW, Exploits::GateFeature).mac_cycles;
        let f = cost(M, DF, DW, Exploits::SkipFeature).mac_cycles;
        let w = cost(M, DF, DW, Exploits::SkipWeight).mac_cycles;
        let both = cost(M, DF, DW, Exploits::SkipBoth).mac_cycles;
        assert_eq!(dense, gate, "gating saves no cycles");
        assert!(f < dense && w < dense);
        assert!(both < f && both < w, "dual sparsity dominates");
    }

    #[test]
    fn energy_ordering_matches_table3() {
        let e = |p| cost(M, DF, DW, p).energy_per_dense_mac;
        assert!(e(Exploits::GateFeature) < e(Exploits::None));
        assert!(e(Exploits::SkipFeature) < e(Exploits::GateFeature));
        assert!(e(Exploits::SkipBoth) < e(Exploits::SkipFeature));
        assert!(e(Exploits::SkipBoth) < e(Exploits::SkipWeight));
    }

    #[test]
    fn performed_macs_track_gated_fraction() {
        // dense performs everything; gate/skip-feature perform df*dense;
        // skip-both performs the must-MACs
        assert_eq!(cost(M, DF, DW, Exploits::None).mac_ops, M);
        let expect = (M as f64 * DF).ceil() as u64;
        assert_eq!(cost(M, DF, DW, Exploits::GateFeature).mac_ops, expect);
        assert_eq!(cost(M, DF, DW, Exploits::SkipFeature).mac_ops, expect);
        // same association as the implementation's gated_fraction
        assert_eq!(
            cost(M, DF, DW, Exploits::SkipBoth).mac_ops,
            (M as f64 * (DF * DW)).ceil() as u64
        );
    }

    #[test]
    fn skip_feature_speedup_is_inverse_density() {
        let dense = cost(M, 0.25, 1.0, Exploits::None);
        let f = cost(M, 0.25, 1.0, Exploits::SkipFeature);
        let speedup = dense.mac_cycles as f64 / f.mac_cycles as f64;
        assert!((speedup - 4.0).abs() < 0.1, "speedup {speedup}");
    }
}
