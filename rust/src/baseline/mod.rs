//! Comparison points of the paper's evaluation.
//!
//! * [`naive`] — the dense output-stationary systolic array ("naïve
//!   design", Fig. 1; "can be basically regarded as the performance of
//!   TPU", Section 5.2). Same convolution mapping as S²Engine, same MAC
//!   clock, 2 MB SRAM, no sparsity support: every zero occupies a PE
//!   cycle. This is the 1× reference of every speedup/efficiency figure.
//! * [`gating`] — partial-sparsity comparators (Eyeriss / Cnvlutin /
//!   Cambricon-X classes) for the quantitative Table III.
//! * [`scnn`] — analytic comparator for SCNN (Parashar et al., ISCA'17),
//!   calibrated to its published characteristics (Cartesian-product PEs,
//!   crossbar contention, 79% dense-mode speed, +33% dense-mode energy).
//! * [`sparten`] — analytic comparator for SparTen (Gondimalla et al.,
//!   MICRO'19): higher speedup than S²Engine but significantly worse
//!   energy due to prefix-sum/permute logic (Table V).

pub mod gating;
pub mod naive;
pub mod scnn;
pub mod sparten;

use crate::MAC_FREQ_MHZ;

/// Wall-clock seconds of `mac_cycles` MAC-clock cycles. Every
/// comparator model shares the paper's 500 MHz MAC clock, so every
/// `*Cost::wall_seconds` delegates here — one definition, one clock.
pub fn wall_seconds(mac_cycles: u64) -> f64 {
    mac_cycles as f64 / (MAC_FREQ_MHZ as f64 * 1e6)
}

#[cfg(test)]
mod tests {
    #[test]
    fn shared_clock_conversion() {
        // 500 MHz: 5e8 cycles is exactly one second
        assert_eq!(super::wall_seconds(500_000_000), 1.0);
        assert_eq!(super::wall_seconds(0), 0.0);
        // and every cost struct's wall goes through the same helper
        let n = super::naive::NaiveCost {
            mac_cycles: 123_456,
            ..Default::default()
        };
        assert_eq!(n.wall_seconds().to_bits(), super::wall_seconds(123_456).to_bits());
    }
}
