//! Comparison points of the paper's evaluation.
//!
//! * [`naive`] — the dense output-stationary systolic array ("naïve
//!   design", Fig. 1; "can be basically regarded as the performance of
//!   TPU", Section 5.2). Same convolution mapping as S²Engine, same MAC
//!   clock, 2 MB SRAM, no sparsity support: every zero occupies a PE
//!   cycle. This is the 1× reference of every speedup/efficiency figure.
//! * [`gating`] — partial-sparsity comparators (Eyeriss / Cnvlutin /
//!   Cambricon-X classes) for the quantitative Table III.
//! * [`scnn`] — analytic comparator for SCNN (Parashar et al., ISCA'17),
//!   calibrated to its published characteristics (Cartesian-product PEs,
//!   crossbar contention, 79% dense-mode speed, +33% dense-mode energy).
//! * [`sparten`] — analytic comparator for SparTen (Gondimalla et al.,
//!   MICRO'19): higher speedup than S²Engine but significantly worse
//!   energy due to prefix-sum/permute logic (Table V).

pub mod gating;
pub mod naive;
pub mod scnn;
pub mod sparten;
