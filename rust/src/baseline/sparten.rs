//! Analytic SparTen comparator (Gondimalla et al., MICRO 2019).
//!
//! SparTen performs sparse vector-vector multiplication with bit-mask
//! inner joins (AND of sparsity bitmasks + prefix-sum to locate pairs)
//! and a greedy load-balancer ("greedy balancing" of chunks across 32
//! filter units). Published characteristics the model is calibrated to
//! (Table V of the S²Engine paper):
//!
//! * speedup vs its dense version on AlexNet/VGG16-class sparsity:
//!   ~5.6× — higher than S²Engine (no systolic transmission constraints,
//!   near-perfect MAC utilisation on must-MACs);
//! * energy efficiency only ~1.4× on memory and ~0.5× on computation —
//!   the prefix-sum circuit and permute network burn more than the
//!   skipped MACs save;
//! * 31 KB of FIFO-class storage, 3.2 mm² of it in 45 nm (large area).

use crate::models::Model;

pub const SPARTEN_MULTIPLIERS: u64 = 1024;
/// Effective utilisation of must-MACs (bit-mask join keeps the
/// multipliers nearly full; load imbalance costs a few percent).
pub const MUST_MAC_UTILIZATION: f64 = 0.92;
/// Energy multiplier on the compute path (prefix-sum + permute overhead
/// per product) — calibrated so the dense-workload energy efficiency is
/// ~0.5× (Table V note).
pub const COMPUTE_ENERGY_OVERHEAD: f64 = 2.0;
/// Memory-path energy factor vs dense (compressed operands): ~1.4×
/// *efficiency*, i.e. 1/1.4 energy.
pub const MEMORY_ENERGY_FACTOR: f64 = 1.0 / 1.4;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparTenCost {
    pub mac_cycles: u64,
    pub mac_ops: u64,
    /// Normalized on-chip energy per dense-MAC-equivalent (dense ideal
    /// accelerator = 1.0), split into compute + memory shares.
    pub energy_per_dense_mac: f64,
}

impl SparTenCost {
    pub fn wall_seconds(&self) -> f64 {
        super::wall_seconds(self.mac_cycles)
    }
}

pub fn cost(dense_macs: u64, df: f64, dw: f64) -> SparTenCost {
    let must = (dense_macs as f64 * df * dw).ceil();
    let mac_cycles =
        (must / (SPARTEN_MULTIPLIERS as f64 * MUST_MAC_UTILIZATION)).ceil() as u64;
    // compute share ~0.6 / memory ~0.4 of a dense design's energy budget
    let compute = 0.6 * df * dw * COMPUTE_ENERGY_OVERHEAD;
    let memory = 0.4 * ((df + dw) / 2.0) * MEMORY_ENERGY_FACTOR;
    SparTenCost {
        mac_cycles,
        mac_ops: must as u64,
        energy_per_dense_mac: compute + memory,
    }
}

pub fn model_cost(model: &Model) -> SparTenCost {
    cost(
        model.total_macs(),
        model.feature_density,
        model.weight_density,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_near_published_at_paper_density() {
        // AlexNet/VGG-class density ~0.38/0.34: speedup vs dense ideal =
        // 1/(df*dw/util) ≈ 7.1; vs the naive *systolic* baseline (which
        // has skew overheads) the paper reports 5.6. Sanity band:
        let c = cost(1_000_000_000, 0.38, 0.34);
        let dense_cycles = 1_000_000_000 / SPARTEN_MULTIPLIERS;
        let speedup = dense_cycles as f64 / c.mac_cycles as f64;
        assert!(speedup > 4.5 && speedup < 9.0, "speedup {speedup}");
    }

    #[test]
    fn beats_s2_on_speed_but_not_energy() {
        // At equal density, SparTen's cycles < a DS-limited systolic
        // design, but its compute energy overhead is large.
        let c = cost(1_000_000, 0.4, 0.35);
        assert!(c.energy_per_dense_mac > 0.2);
        let dense = cost(1_000_000, 1.0, 1.0);
        // dense workload: energy ≥ dense ideal (efficiency ≤ 1)
        assert!(dense.energy_per_dense_mac > 1.0);
    }

    #[test]
    fn wall_seconds_sane() {
        let c = SparTenCost {
            mac_cycles: crate::MAC_FREQ_MHZ * 1_000_000,
            mac_ops: 0,
            energy_per_dense_mac: 0.0,
        };
        assert!((c.wall_seconds() - 1.0).abs() < 1e-9);
    }
}
