//! The naïve dense output-stationary systolic array — the paper's 1×
//! baseline (Section 5.2).
//!
//! Identical mapping to S²Engine (each PE owns one convolution; features
//! stream along rows, weights down columns) but uncompressed: every PE
//! consumes one dense element per MAC cycle, zeros included, and the
//! whole reduction vector of length K = kh·kw·cin is walked for every
//! tile. Being fully regular, its timing is closed-form; no cycle loop is
//! needed (and the paper treats it analytically too — its dense latency
//! has no data dependence).

use crate::config::{ArrayConfig, BufferConfig};
use crate::models::{LayerDesc, Model};

/// Closed-form cost of a layer on the naive array.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NaiveCost {
    /// MAC-clock cycles for the whole layer.
    pub mac_cycles: u64,
    /// MAC operations (all dense — nothing is gated or skipped).
    pub mac_ops: u64,
    /// FB element reads (dense 8-bit elements, per-row copies: the
    /// no-overlap-reuse arrangement of Section 3.1).
    pub fb_byte_reads: u64,
    /// WB element reads.
    pub wb_byte_reads: u64,
    /// DRAM traffic in bytes (uncompressed features + weights, loaded
    /// once per layer).
    pub dram_bytes: u64,
    /// SRAM bytes that must be resident (uncompressed, with per-row
    /// window copies).
    pub sram_resident_bytes: u64,
}

impl NaiveCost {
    pub fn wall_seconds(&self) -> f64 {
        super::wall_seconds(self.mac_cycles)
    }
}

/// Cost of one layer on an R×C naive array with the paper's 2 MB SRAM.
pub fn layer_cost(layer: &LayerDesc, cfg: &ArrayConfig) -> NaiveCost {
    layer_cost_with_sram(layer, cfg, BufferConfig::NAIVE_DEFAULT.sram_bytes)
}

/// Cost of one layer with explicit SRAM capacity. Uncompressed per-row
/// im2col copies must be resident (Section 3.1: no overlap reuse means
/// "three separate FBs as three copies"); a layer whose working set
/// exceeds the buffers re-streams features from DRAM once per overlap
/// copy (Section 5.2: the 2 MB provisioning "holds 66 out of 71 layers").
pub fn layer_cost_with_sram(
    layer: &LayerDesc,
    cfg: &ArrayConfig,
    sram_bytes: usize,
) -> NaiveCost {
    let k = layer.k_len() as u64;
    let m = layer.num_convs() as u64;
    let n = layer.cout as u64;
    let rows = cfg.rows as u64;
    let cols = cfg.cols as u64;
    let row_tiles = m.div_ceil(rows);
    let col_tiles = n.div_ceil(cols);
    let tiles = row_tiles * col_tiles;

    // Each tile: K cycles of streaming + systolic skew fill (R-1 + C-1)
    // + result drain (R, in-order down each column). Back-to-back tiles
    // overlap fill with the previous drain, so charge max(fill, drain)
    // once per tile.
    let per_tile = k + (rows - 1) + (cols - 1) + rows;
    let mac_cycles = tiles * per_tile;

    let mac_ops = m * k * n; // dense

    // Dense streams: every tile re-reads K bytes per active row and per
    // active column (8-bit data).
    let fb_byte_reads = row_tiles * col_tiles * rows.min(m) * k;
    let wb_byte_reads = row_tiles * col_tiles * cols.min(n) * k;

    let feat_bytes = layer.input_elems();
    let weight_bytes = layer.params();
    // Working set: per-row im2col copies (M*K bytes) + weights. When it
    // spills the buffers, every overlap copy of the features re-streams
    // from DRAM (bounded by the kh*kw overlap factor).
    let resident = m * k + weight_bytes;
    let spill_factor = resident
        .div_ceil(sram_bytes as u64)
        .clamp(1, (layer.kh * layer.kw) as u64);
    NaiveCost {
        mac_cycles,
        mac_ops,
        fb_byte_reads,
        wb_byte_reads,
        dram_bytes: feat_bytes * spill_factor + weight_bytes,
        sram_resident_bytes: resident,
    }
}

/// Whole-model cost (sum over layers; layers run back-to-back).
pub fn model_cost(model: &Model, cfg: &ArrayConfig) -> NaiveCost {
    let mut total = NaiveCost::default();
    for l in &model.layers {
        let c = layer_cost(l, cfg);
        total.mac_cycles += c.mac_cycles;
        total.mac_ops += c.mac_ops;
        total.fb_byte_reads += c.fb_byte_reads;
        total.wb_byte_reads += c.wb_byte_reads;
        total.dram_bytes += c.dram_bytes;
        total.sram_resident_bytes = total.sram_resident_bytes.max(c.sram_resident_bytes);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn dense_macs_match_layer_arithmetic() {
        let m = zoo::alexnet();
        let cfg = ArrayConfig::new(16, 16);
        for l in &m.layers {
            let c = layer_cost(l, &cfg);
            assert_eq!(c.mac_ops, l.macs());
        }
    }

    #[test]
    fn cycles_scale_inverse_with_array_size() {
        let m = zoo::vgg16();
        let l = &m.layers[5];
        let small = layer_cost(l, &ArrayConfig::new(16, 16));
        let big = layer_cost(l, &ArrayConfig::new(32, 32));
        let ratio = small.mac_cycles as f64 / big.mac_cycles as f64;
        assert!(
            ratio > 3.0 && ratio < 5.0,
            "4x PEs should be ~4x faster, got {ratio}"
        );
    }

    #[test]
    fn utilization_near_one_for_big_layers() {
        // cycles * PEs should be close to dense MACs for well-tiled layers
        let m = zoo::vgg16();
        let cfg = ArrayConfig::new(16, 16);
        let l = m.layer("conv3_2").unwrap();
        let c = layer_cost(l, &cfg);
        let util = c.mac_ops as f64 / (c.mac_cycles as f64 * 256.0);
        assert!(util > 0.85, "utilization {util}");
    }

    #[test]
    fn model_cost_sums_layers() {
        let m = zoo::alexnet();
        let cfg = ArrayConfig::new(16, 16);
        let total = model_cost(&m, &cfg);
        let sum: u64 = m
            .layers
            .iter()
            .map(|l| layer_cost(l, &cfg).mac_cycles)
            .sum();
        assert_eq!(total.mac_cycles, sum);
        assert_eq!(total.mac_ops, m.total_macs());
    }

    #[test]
    fn dram_spill_on_oversized_layers() {
        // VGG conv1_2: M*K ~ 28 MB >> 2 MB -> features re-stream
        let m = zoo::vgg16();
        let l = m.layer("conv1_2").unwrap();
        let c = layer_cost(l, &ArrayConfig::new(16, 16));
        assert!(c.sram_resident_bytes > 2 << 20);
        assert!(c.dram_bytes > l.input_elems() + l.params());
        // a small layer (AlexNet conv3: ~1.3 MB working set) fits 2 MB
        // and streams exactly once
        let a = zoo::alexnet();
        let small = a.layer("conv3").unwrap();
        let cs = layer_cost(small, &ArrayConfig::new(16, 16));
        assert_eq!(cs.dram_bytes, small.input_elems() + small.params());
    }

    #[test]
    fn wall_time_uses_mac_clock() {
        let c = NaiveCost {
            mac_cycles: 500_000_000,
            ..Default::default()
        };
        assert!((c.wall_seconds() - 1.0).abs() < 1e-9);
    }
}
