"""AOT pipeline checks: every artifact lowers to parseable HLO text with
the entry signature the Rust runtime expects, and the manifest is
consistent with the model."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def gemm_hlo():
    return aot.lower_gemm()


@pytest.fixture(scope="module")
def quant_hlo():
    return aot.lower_relu_quant()


def test_gemm_hlo_nonempty(gemm_hlo):
    assert "ENTRY" in gemm_hlo and len(gemm_hlo) > 500


def test_gemm_hlo_shapes_in_signature(gemm_hlo):
    # parameters f32[64,144] and f32[144,32] must appear
    assert f"f32[{aot.GEMM_M},{aot.GEMM_K}]" in gemm_hlo
    assert f"f32[{aot.GEMM_K},{aot.GEMM_N}]" in gemm_hlo


def test_gemm_hlo_returns_tuple(gemm_hlo):
    # lowered with return_tuple=True: root is a tuple of one f32[64,32]
    assert f"(f32[{aot.GEMM_M},{aot.GEMM_N}]" in gemm_hlo


def test_gemm_hlo_no_custom_calls(gemm_hlo):
    """interpret=True must lower pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    assert "custom-call" not in gemm_hlo.lower() or "mosaic" not in gemm_hlo.lower()


def test_relu_quant_hlo(quant_hlo):
    assert "ENTRY" in quant_hlo
    assert f"f32[{aot.QUANT_LEN}]" in quant_hlo
    assert f"s8[{aot.QUANT_LEN}]" in quant_hlo


def test_cnn_features_hlo():
    text = aot.lower_cnn_features()
    assert "ENTRY" in text
    assert "f32[4,32,32,3]" in text
    # all four feature outputs present in the root tuple
    assert "f32[4,32,32,32]" in text
    assert "f32[4,16,16,64]" in text


def test_manifest_matches_model():
    m = aot.manifest()
    assert m["group_len"] == 16
    assert len(m["cnn"]["layers"]) == len(model.LAYERS)
    for entry, spec in zip(m["cnn"]["layers"], model.LAYERS):
        assert entry["name"] == spec.name
        assert entry["cout"] == spec.cout
        assert entry["cin_padded"] % 16 == 0
    json.dumps(m)  # must be serializable
