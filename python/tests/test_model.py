"""L2 correctness: the S2Net model through the Pallas path vs lax convs.

Verifies the im2col/grouping reshape logic (the exact transform the Rust
compiler re-implements for the ECOO dataflow), the full feature stack,
and the int8 quantized inter-layer variant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def image():
    return jax.random.normal(jax.random.PRNGKey(7), (model.BATCH, 32, 32, 3))


# ----------------------------------------------------------- im2col path --


@settings(max_examples=15, deadline=None)
@given(
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    c=st.sampled_from([16, 32]),
    d=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**16),
)
def test_conv_im2col_equals_lax(k, stride, c, d, seed):
    """Property: im2col+GEMM == direct lax conv for any kernel/stride."""
    pad = k // 2
    key1, key2 = jax.random.split(jax.random.PRNGKey(seed))
    feat = jax.random.normal(key1, (2, 16, 16, c))
    w = jax.random.normal(key2, (k, k, c, d)) * 0.1
    got = ref.conv2d_im2col_ref(feat, w, stride, pad)
    want = ref.conv2d_ref(feat, w, stride, pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_layer_pallas_equals_lax(params):
    """Each S2Net layer through the Pallas kernel == lax conv."""
    feat = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 16))
    spec = model.LAYERS[1]  # 3x3 32->32 s2 — feat padded 16->32 internally
    w = params[1]
    got = model.conv_layer(feat, w, spec, relu=True)
    padded = jnp.pad(feat, ((0, 0), (0, 0), (0, 0), (0, 16)))
    want = ref.conv2d_ref(padded, w, spec.stride, spec.pad, relu=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- full network --


def test_forward_features_shapes(params, image):
    feats = model.forward_features(image, *params[:4])
    assert [tuple(f.shape) for f in feats] == [
        (4, 32, 32, 32),
        (4, 16, 16, 32),
        (4, 16, 16, 64),
        (4, 16, 16, 64),
    ]


def test_forward_features_vs_lax(params, image):
    """Whole conv stack equals a lax-only reimplementation."""
    feats = model.forward_features(image, *params[:4])
    f = image
    for spec, w in zip(model.LAYERS, params[:4]):
        cin = w.shape[2]
        if f.shape[-1] < cin:
            f = jnp.pad(f, ((0, 0), (0, 0), (0, 0), (0, cin - f.shape[-1])))
        f = ref.conv2d_ref(f, w, spec.stride, spec.pad, relu=True)
    np.testing.assert_allclose(feats[-1], f, rtol=1e-3, atol=1e-4)


def test_features_are_sparse(params, image):
    """ReLU must actually produce sparsity — the whole premise of the
    paper's feature-sparsity exploitation."""
    feats = model.forward_features(image, *params[:4])
    for f in feats:
        density = float((np.asarray(f) != 0).mean())
        assert 0.05 < density < 0.95, f"degenerate density {density}"


def test_forward_logits_shape(params, image):
    logits = model.forward(image, params)
    assert logits.shape == (model.BATCH, model.NUM_CLASSES)
    assert bool(jnp.isfinite(logits).all())


def test_forward_quantized_close_to_float(params, image):
    """int8 inter-layer path tracks the float path within quant error."""
    logits_f = model.forward(image, params)
    logits_q, qfeats = model.forward_quantized(image, params)
    assert all(q.dtype == jnp.int8 for q in qfeats)
    # correlation, not allclose: 4 layers of int8 re-quantization
    a = np.asarray(logits_f).ravel()
    b = np.asarray(logits_q).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.95, f"quantized path diverged (corr={corr})"


def test_pruned_weights_flow_through(params, image):
    """Magnitude-pruned weights (as the Rust side generates) still produce
    valid, sparser features — the real-feature mode contract."""
    pruned = []
    for w in params[:4]:
        thresh = jnp.quantile(jnp.abs(w), 0.7)
        pruned.append(jnp.where(jnp.abs(w) >= thresh, w, 0.0))
    feats = model.forward_features(image, *pruned)
    for f in feats:
        assert bool(jnp.isfinite(f).all())
    w_density = float((np.asarray(pruned[2]) != 0).mean())
    assert w_density < 0.35


def test_init_params_padded_channels_zero(params):
    """Padded input channels of conv1 must be exactly zero so that the
    3->16 channel padding contributes nothing."""
    w1 = np.asarray(params[0])
    assert (w1[:, :, 3:, :] == 0).all()
