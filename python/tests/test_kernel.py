"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

This is the CORE numeric signal of the reproduction — the grouped GEMM is
the datapath every conv in the exported artifacts flows through, so any
mismatch here propagates into the feature maps the simulator consumes.
Hypothesis sweeps shapes/dtypes; fixed cases pin the exact artifact shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grouped_gemm import (
    GROUP_LEN,
    grouped_gemm,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.quant import relu_quant
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ---------------------------------------------------------------- GEMM --


class TestGroupedGemmFixed:
    def test_artifact_shape(self):
        """The exact shape exported to gemm.hlo.txt."""
        x, y = rand(0, (64, 144)), rand(1, (144, 32))
        np.testing.assert_allclose(
            grouped_gemm(x, y), ref.gemm_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_single_tile(self):
        x, y = rand(2, (32, 16)), rand(3, (16, 32))
        np.testing.assert_allclose(
            grouped_gemm(x, y), ref.gemm_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_many_group_steps(self):
        """K = 10 groups: exercises the output-stationary accumulation."""
        x, y = rand(4, (32, 160)), rand(5, (160, 64))
        np.testing.assert_allclose(
            grouped_gemm(x, y), ref.gemm_ref(x, y), rtol=1e-4, atol=1e-5
        )

    def test_fused_relu(self):
        x, y = rand(6, (64, 48)), rand(7, (48, 32))
        np.testing.assert_allclose(
            grouped_gemm(x, y, relu=True),
            ref.gemm_relu_ref(x, y),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_relu_actually_clips(self):
        x, y = rand(8, (32, 16)), rand(9, (16, 32))
        out = np.asarray(grouped_gemm(x, y, relu=True))
        assert (out >= 0).all()
        # the unfused result must contain negatives for this to be a test
        assert (np.asarray(grouped_gemm(x, y)) < 0).any()

    def test_zero_inputs(self):
        x = jnp.zeros((32, 32))
        y = jnp.zeros((32, 32))
        assert np.asarray(grouped_gemm(x, y)).sum() == 0.0

    def test_bf16_inputs_f32_accum(self):
        x = rand(10, (32, 32), jnp.bfloat16)
        y = rand(11, (32, 32), jnp.bfloat16)
        np.testing.assert_allclose(
            grouped_gemm(x, y), ref.gemm_ref(x, y), rtol=1e-2, atol=1e-2
        )

    def test_rejects_untiled_shapes(self):
        with pytest.raises(ValueError):
            grouped_gemm(rand(0, (33, 16)), rand(1, (16, 32)))
        with pytest.raises(ValueError):
            grouped_gemm(rand(0, (32, 15)), rand(1, (15, 32)))
        with pytest.raises(ValueError):
            grouped_gemm(rand(0, (32, 16)), rand(1, (32, 32)))

    def test_custom_block_sizes(self):
        x, y = rand(12, (64, 64)), rand(13, (64, 64))
        for bm, bn in [(16, 16), (64, 64), (16, 64)]:
            np.testing.assert_allclose(
                grouped_gemm(x, y, bm=bm, bn=bn),
                ref.gemm_ref(x, y),
                rtol=1e-4,
                atol=1e-5,
            )


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 6),
    ni=st.integers(1, 3),
    seed=st.integers(0, 2**16),
    relu=st.booleans(),
)
def test_grouped_gemm_hypothesis(mi, ki, ni, seed, relu):
    """Property: for any (bm,bn,group)-tiled shape, kernel == oracle."""
    m, k, n = 32 * mi, GROUP_LEN * ki, 32 * ni
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    y = jax.random.normal(ky, (k, n))
    oracle = ref.gemm_relu_ref(x, y) if relu else ref.gemm_ref(x, y)
    np.testing.assert_allclose(
        grouped_gemm(x, y, relu=relu), oracle, rtol=1e-4, atol=1e-5
    )


# ----------------------------------------------------------- relu+quant --


class TestReluQuant:
    def test_matches_ref(self):
        x = rand(20, (1024,)) * 3.0
        np.testing.assert_array_equal(
            relu_quant(x, 0.05), ref.relu_quant_ref(x, 0.05)
        )

    def test_negative_all_zero(self):
        x = -jnp.abs(rand(21, (512,)))
        assert np.asarray(relu_quant(x, 0.05)).sum() == 0

    def test_saturation(self):
        x = jnp.full((256,), 1e6)
        assert (np.asarray(relu_quant(x, 0.05)) == 127).all()

    def test_unpadded_length(self):
        """Length not a multiple of the block: pad/strip path."""
        x = rand(22, (1000,))
        np.testing.assert_array_equal(
            relu_quant(x, 0.1), ref.relu_quant_ref(x, 0.1)
        )

    def test_multidim(self):
        x = rand(23, (4, 16, 16, 32))
        np.testing.assert_array_equal(
            relu_quant(x, 0.02), ref.relu_quant_ref(x, 0.02)
        )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5000),
    scale=st.floats(1e-3, 1.0),
    seed=st.integers(0, 2**16),
)
def test_relu_quant_hypothesis(n, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2.0
    got = np.asarray(relu_quant(x, scale))
    want = np.asarray(ref.relu_quant_ref(x, scale))
    # rounding of exact .5 values may differ by 1 LSB between the padded
    # pallas path and the oracle on some backends; require exactness
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int8
    assert (got >= 0).all()


# ------------------------------------------------------ structural perf --


class TestStructuralEstimates:
    def test_vmem_footprint_default_fits(self):
        """Default tiles must fit VMEM (16 MiB/core) with huge headroom —
        the budget recorded in DESIGN.md §Perf."""
        assert vmem_footprint_bytes() < 64 * 1024

    def test_vmem_scales_with_tiles(self):
        assert vmem_footprint_bytes(128, 128) > vmem_footprint_bytes(32, 32)

    def test_mxu_estimate_bounds(self):
        u = mxu_utilization_estimate(1024, 256, 512)
        assert 0.0 < u <= 1.0

    def test_mxu_estimate_monotone_in_tiles(self):
        assert mxu_utilization_estimate(
            1024, 256, 512, bm=128, bn=128
        ) >= mxu_utilization_estimate(1024, 256, 512, bm=32, bn=32)
