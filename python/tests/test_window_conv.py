"""The windowed direct-conv Pallas kernel vs the lax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.window_conv import window_conv


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * 0.5


class TestWindowConvFixed:
    def test_3x3_same_padding(self):
        feat = rand(0, (2, 8, 8, 16))
        w = rand(1, (3, 3, 16, 8))
        got = window_conv(feat, w, pad=1)
        want = ref.conv2d_ref(feat, w, stride=1, pad=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_1x1(self):
        feat = rand(2, (1, 6, 6, 32))
        w = rand(3, (1, 1, 32, 16))
        got = window_conv(feat, w)
        want = ref.conv2d_ref(feat, w, stride=1, pad=0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_fused_relu(self):
        feat = rand(4, (1, 5, 5, 8))
        w = rand(5, (3, 3, 8, 8))
        got = window_conv(feat, w, pad=1, relu=True)
        want = ref.conv2d_ref(feat, w, 1, 1, relu=True)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        assert (np.asarray(got) >= 0).all()

    def test_valid_padding_5x5(self):
        feat = rand(6, (1, 9, 9, 4))
        w = rand(7, (5, 5, 4, 4))
        got = window_conv(feat, w)
        want = ref.conv2d_ref(feat, w, 1, 0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_channel_mismatch_rejected(self):
        with pytest.raises(ValueError):
            window_conv(rand(0, (1, 4, 4, 8)), rand(1, (3, 3, 16, 4)))

    def test_agrees_with_grouped_gemm_path(self):
        """Both L1 kernels must compute the same convolution."""
        feat = rand(8, (2, 8, 8, 16))
        w = rand(9, (3, 3, 16, 32))
        direct = window_conv(feat, w, pad=1)
        im2col = ref.conv2d_im2col_ref(feat, w, 1, 1)
        np.testing.assert_allclose(direct, im2col, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    k=st.sampled_from([1, 3]),
    hw=st.integers(4, 9),
    c=st.sampled_from([4, 16]),
    d=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_window_conv_hypothesis(k, hw, c, d, seed):
    key1, key2 = jax.random.split(jax.random.PRNGKey(seed))
    feat = jax.random.normal(key1, (1, hw, hw, c))
    w = jax.random.normal(key2, (k, k, c, d)) * 0.2
    got = window_conv(feat, w, pad=k // 2)
    want = ref.conv2d_ref(feat, w, 1, k // 2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
