"""AOT pipeline: lower the L2 model (with its L1 Pallas kernels) to HLO
*text* artifacts that the Rust runtime loads over PJRT.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written (plus `manifest.json` describing shapes for Rust):

    gemm.hlo.txt          (x[64,144] f32, y[144,32] f32) -> (o[64,32],)
    cnn_features.hlo.txt  (img[4,32,32,3], w1..w4)       -> (f1..f4)
    relu_quant.hlo.txt    (x[4096] f32)                  -> (q[4096] i8,)

Usage (from python/): python -m compile.aot --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.quant import relu_quant
from .kernels.ref import GROUP_LEN

#: Fixed GEMM artifact shape: M=64 rows of patches, K=9*16 (a 3x3 kernel
#: over one 16-channel group-padded input), N=32 output channels.
GEMM_M, GEMM_K, GEMM_N = 64, 144, 32
QUANT_LEN = 4096


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps with to_tuple{1,N})."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_gemm() -> str:
    return to_hlo_text(
        jax.jit(model.gemm_entry).lower(
            _spec((GEMM_M, GEMM_K)), _spec((GEMM_K, GEMM_N))
        )
    )


def lower_cnn_features() -> str:
    img = _spec((model.BATCH, model.IMG_HW, model.IMG_HW, 3))
    wspecs = [
        _spec((s.kh, s.kw, model._pad_cin(s.cin), s.cout)) for s in model.LAYERS
    ]
    return to_hlo_text(jax.jit(model.forward_features).lower(img, *wspecs))


def lower_relu_quant() -> str:
    def entry(x):
        return (relu_quant(x, model.QUANT_SCALE),)

    return to_hlo_text(jax.jit(entry).lower(_spec((QUANT_LEN,))))


def manifest() -> dict:
    """Shape/layout metadata consumed by rust/src/runtime/artifacts.rs."""
    return {
        "group_len": GROUP_LEN,
        "quant_scale": model.QUANT_SCALE,
        "gemm": {"m": GEMM_M, "k": GEMM_K, "n": GEMM_N, "file": "gemm.hlo.txt"},
        "relu_quant": {"len": QUANT_LEN, "file": "relu_quant.hlo.txt"},
        "cnn": {
            "file": "cnn_features.hlo.txt",
            "batch": model.BATCH,
            "img_hw": model.IMG_HW,
            "img_c": 3,
            "layers": [
                {
                    "name": s.name,
                    "kh": s.kh,
                    "kw": s.kw,
                    "cin": s.cin,
                    "cin_padded": model._pad_cin(s.cin),
                    "cout": s.cout,
                    "stride": s.stride,
                    "pad": s.pad,
                }
                for s in model.LAYERS
            ],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--out", default=None, help="legacy single-file alias; writes gemm HLO"
    )
    args = ap.parse_args()

    outdir = args.outdir
    if args.out:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    jobs = {
        "gemm.hlo.txt": lower_gemm,
        "cnn_features.hlo.txt": lower_cnn_features,
        "relu_quant.hlo.txt": lower_relu_quant,
    }
    for fname, fn in jobs.items():
        text = fn()
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")

    # legacy alias expected by the original Makefile stamp rule
    alias = os.path.join(outdir, "model.hlo.txt")
    with open(os.path.join(outdir, "gemm.hlo.txt")) as src, open(alias, "w") as dst:
        dst.write(src.read())

    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote manifest        {mpath}")


if __name__ == "__main__":
    main()
