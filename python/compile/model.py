"""L2: the JAX model — a small CNN whose conv layers run through the L1
Pallas grouped-GEMM kernel.

This is the "real numerics" half of the reproduction (DESIGN.md §3): the
S2Engine evaluation needs *real ReLU feature maps* whose sparsity drives
the cycle-accurate simulator. `forward_features` is AOT-lowered by
`aot.py` into `artifacts/cnn_features.hlo.txt`; the Rust runtime executes
it over PJRT with pruned weights and feeds the resulting sparse features
into the compiler + simulator (end_to_end example, real-feature mode).

The network ("S2Net") is CIFAR-scale so the artifact compiles in seconds:

    conv1 3x3  3->32  s1 p1   32x32x32     (input channels padded 3->16)
    conv2 3x3 32->32  s2 p1   16x16x32
    conv3 3x3 32->64  s1 p1   16x16x64
    conv4 1x1 64->64  s1 p0   16x16x64
    GAP + linear 64->10

Every conv is im2col + `grouped_gemm` (Pallas, fused ReLU), so all hot
FLOPs lower through the L1 kernel. All channel counts are multiples of the
ECOO GROUP_LEN=16 and the kernel tiles (32), mirroring the compiler's
group padding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.grouped_gemm import grouped_gemm
from .kernels.quant import relu_quant
from .kernels.ref import GROUP_LEN, im2col, kernel2mat

#: Activation quantization scale used by the int8 inter-layer path; fixed
#: at export time and recorded in the artifact manifest.
QUANT_SCALE = 0.05


@dataclass(frozen=True)
class LayerSpec:
    """One conv layer of S2Net (mirrors rust/src/models/ LayerDesc)."""

    name: str
    kh: int
    kw: int
    cin: int
    cout: int
    stride: int
    pad: int

    def out_hw(self, h: int, w: int) -> Tuple[int, int]:
        oh = (h + 2 * self.pad - self.kh) // self.stride + 1
        ow = (w + 2 * self.pad - self.kw) // self.stride + 1
        return oh, ow


LAYERS: List[LayerSpec] = [
    LayerSpec("conv1", 3, 3, 3, 32, 1, 1),
    LayerSpec("conv2", 3, 3, 32, 32, 2, 1),
    LayerSpec("conv3", 3, 3, 32, 64, 1, 1),
    LayerSpec("conv4", 1, 1, 64, 64, 1, 0),
]

#: Fixed batch/image shape baked into the AOT artifact.
BATCH = 4
IMG_HW = 32
NUM_CLASSES = 10


def _pad_cin(c: int) -> int:
    """Input channels are zero-padded to the group length so the im2col K
    axis tiles by GROUP_LEN (padding zeros compress to EOG placeholders in
    the ECOO flow — see ref.pad_to_group)."""
    return c if c % GROUP_LEN == 0 else c + (GROUP_LEN - c % GROUP_LEN)


def init_params(key: jax.Array) -> List[jnp.ndarray]:
    """He-init conv weights, shape [KH, KW, Cin_padded, Cout] per layer,
    plus the [64, NUM_CLASSES] classifier matrix (last entry)."""
    params: List[jnp.ndarray] = []
    for spec in LAYERS:
        key, sub = jax.random.split(key)
        cin = _pad_cin(spec.cin)
        fan_in = spec.kh * spec.kw * cin
        w = jax.random.normal(sub, (spec.kh, spec.kw, cin, spec.cout)) * jnp.sqrt(
            2.0 / fan_in
        )
        if cin != spec.cin:
            # zero the padded input channels so they contribute nothing
            w = w.at[:, :, spec.cin :, :].set(0.0)
        params.append(w.astype(jnp.float32))
    key, sub = jax.random.split(key)
    params.append(
        (jax.random.normal(sub, (LAYERS[-1].cout, NUM_CLASSES)) * 0.05).astype(
            jnp.float32
        )
    )
    return params


def conv_layer(
    feat: jnp.ndarray, w: jnp.ndarray, spec: LayerSpec, *, relu: bool = True
) -> jnp.ndarray:
    """One conv through the Pallas path: channel-pad, im2col, grouped GEMM
    with fused ReLU, reshape back to NHWC."""
    n, h, wd, c = feat.shape
    cin = w.shape[2]
    if c < cin:
        feat = jnp.pad(feat, ((0, 0), (0, 0), (0, 0), (0, cin - c)))
    patches = im2col(feat, spec.kh, spec.kw, spec.stride, spec.pad)
    out = grouped_gemm(patches, kernel2mat(w), relu=relu)
    oh, ow = spec.out_hw(h, wd)
    return out.reshape(n, oh, ow, spec.cout)


def forward_features(x: jnp.ndarray, *weights: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """Run the conv stack, returning every post-ReLU feature map.

    This is the function AOT-exported for the Rust runtime: signature
    (image, w1..w4) -> (f1, f2, f3, f4). Zeros in the returned maps are
    the *real* feature sparsity the simulator consumes.
    """
    feats = []
    f = x
    for spec, w in zip(LAYERS, weights):
        f = conv_layer(f, w, spec, relu=True)
        feats.append(f)
    return tuple(feats)


def forward(x: jnp.ndarray, params: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Full classifier forward: conv stack + GAP + linear -> logits."""
    feats = forward_features(x, *params[: len(LAYERS)])
    pooled = feats[-1].mean(axis=(1, 2))  # [N, 64]
    return pooled @ params[-1]


def forward_quantized(
    x: jnp.ndarray, params: Sequence[jnp.ndarray], scale: float = QUANT_SCALE
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...]]:
    """int8 inter-layer variant: each activation map passes through the
    Pallas relu_quant kernel and is dequantized before the next conv —
    modelling the paper's 8-bit datapath between layers (Section 4.5).
    Returns (logits, int8 feature maps)."""
    qfeats = []
    f = x
    for spec, w in zip(LAYERS, params[: len(LAYERS)]):
        pre = conv_layer(f, w, spec, relu=False)
        q = relu_quant(pre, scale)
        qfeats.append(q)
        f = q.astype(jnp.float32) * scale
    pooled = f.mean(axis=(1, 2))
    return pooled @ params[-1], tuple(qfeats)


def gemm_entry(x: jnp.ndarray, y: jnp.ndarray) -> Tuple[jnp.ndarray]:
    """Bare grouped-GEMM entry point exported as its own artifact for the
    Rust runtime's numeric cross-check (runtime::verify)."""
    return (grouped_gemm(x, y, relu=False),)
