"""L1 Pallas kernel: group-tiled GEMM — the compute hot-spot of S2Engine.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): S2Engine is a
sparse systolic ASIC whose PEs skip zero operand pairs. Fine-grained
zero-skipping does not map onto the TPU MXU, so this kernel implements the
paper's *dataflow* insight instead — the channel-grouped schedule:

  * the K (reduction) axis is tiled at GROUP_LEN=16, exactly the ECOO
    group length. One grid step over axis 2 streams one "group" of every
    patch row through the MXU, mirroring one CE-array period (Fig. 8)
    where one group is resident per CE;
  * the output block stays resident in VMEM across all K steps — the
    output-stationary dataflow of the paper's PE array (each PE owns one
    output element; here each VMEM tile owns a bm x bn output block);
  * the BlockSpec index maps express the HBM<->VMEM schedule that the
    paper expresses with FIFO broadcasts: the x-tile for (i, k) is reused
    across all j (feature reuse), the y-tile for (k, j) across all i
    (weight reuse), and consecutive k-tiles of the same i row realize the
    overlap reuse the CE array provides.

`interpret=True` everywhere — the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is *estimated* structurally in
DESIGN.md (VMEM footprint + MXU utilization), never from interpret-mode
wallclock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GROUP_LEN

#: Default output tile. 8x128 lanes per MXU pass; bm=bn=32 keeps the toy
#: CIFAR-scale shapes divisible while still exercising multi-tile grids.
DEFAULT_BM = 32
DEFAULT_BN = 32


def _gemm_kernel(x_ref, y_ref, o_ref, *, relu: bool, nsteps: int):
    """Grid = (M/bm, N/bn, K/GROUP_LEN); axis 2 is the group stream.

    o_ref is revisited for every k step (output stationary): zero it on the
    first group, accumulate a bm x bn MXU product per group, and apply the
    optional fused ReLU on the last group.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    if relu:
        @pl.when(k == nsteps - 1)
        def _activate():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def grouped_gemm(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    relu: bool = False,
) -> jnp.ndarray:
    """Compute ``x @ y`` (optionally fused ReLU) with the grouped schedule.

    Requires M % bm == 0, N % bn == 0 and K % GROUP_LEN == 0 (the compiler
    pads to the group length anyway — `ref.pad_to_group`). f32 output.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if m % bm or n % bn or k % GROUP_LEN:
        raise ValueError(
            f"shape ({m},{k})x({k2},{n}) not tiled by bm={bm}, bn={bn}, "
            f"group={GROUP_LEN}"
        )
    nsteps = k // GROUP_LEN
    kernel = functools.partial(_gemm_kernel, relu=relu, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nsteps),
        in_specs=[
            # feature-patch tile: reused across all j (feature reuse)
            pl.BlockSpec((bm, GROUP_LEN), lambda i, j, kk: (i, kk)),
            # weight tile: reused across all i (weight reuse)
            pl.BlockSpec((GROUP_LEN, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN) -> int:
    """Per-step VMEM residency of the kernel, used for the structural perf
    analysis in DESIGN.md (interpret-mode wallclock is meaningless).

    One x tile (bm x 16 f32), one y tile (16 x bn f32) and the resident
    output block (bm x bn f32).
    """
    return 4 * (bm * GROUP_LEN + GROUP_LEN * bn + bm * bn)


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int = DEFAULT_BM,
                             bn: int = DEFAULT_BN) -> float:
    """Fraction of 128x128 MXU lanes busy per pass for this tiling —
    min(bm,128)*min(bn,128)/128^2 scaled by K-stream occupancy (the
    16-deep group tile fills 16/128 of the systolic depth per pass; on a
    real TPU we would fuse 8 groups per pass, which the compiler's group
    coalescing mirrors)."""
    lanes = (min(bm, 128) * min(bn, 128)) / (128.0 * 128.0)
    depth = min(GROUP_LEN * 8, 128) / 128.0  # 8-group coalescing
    return lanes * depth
