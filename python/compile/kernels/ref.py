"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Everything in this module is the *specification*: the Pallas kernels in
`grouped_gemm.py` / `quant.py` must match these functions bit-for-bit (f32)
or within quantization tolerance (int8 path). The pytest suite in
`python/tests/` asserts that equivalence across a hypothesis-driven sweep
of shapes and dtypes.

The S2Engine mapping context: the paper reshapes each convolution into a
1-D dataflow grouped along channels at GROUP_LEN=16 (Fig. 5 / Fig. 8).
Here the same grouping shows up as the K-tile of the GEMM: `im2col`
produces a patch matrix whose K axis is ordered channel-group-major, so
one ECOO group in the paper == one K-tile of 16 in the kernels.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

#: ECOO group length from the paper (Section 4.2): 4-bit offsets.
GROUP_LEN = 16


def gemm_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Plain f32 GEMM — the oracle for the Pallas grouped GEMM."""
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))


def gemm_relu_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """GEMM with fused ReLU — oracle for the fused kernel variant."""
    return jnp.maximum(gemm_ref(x, y), 0.0)


def relu_quant_ref(x: jnp.ndarray, scale: float) -> jnp.ndarray:
    """ReLU then symmetric int8 quantization — oracle for the quant kernel.

    Matches the paper's 8-bit datapath (Section 4.5): values are clipped to
    [0, 127] after ReLU (post-ReLU data is non-negative).
    """
    q = jnp.round(jnp.maximum(x, 0.0) / scale)
    return jnp.clip(q, 0, 127).astype(jnp.int8)


def dequant_ref(q: jnp.ndarray, scale: float) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def pad_to_group(x: np.ndarray, axis: int, group: int = GROUP_LEN) -> np.ndarray:
    """Zero-pad `axis` of `x` up to a multiple of `group`.

    The compiler does the same padding before ECOO encoding: an all-zero
    tail group compresses to a single EOG placeholder, so padding is free
    in the compressed dataflow.
    """
    n = x.shape[axis]
    pad = (-n) % group
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def im2col(feat: jnp.ndarray, kh: int, kw: int, stride: int, pad: int) -> jnp.ndarray:
    """NHWC feature map -> patch matrix [N*OH*OW, KH*KW*C].

    Patch K-axis layout is (kh, kw, c) with c fastest — i.e. contiguous
    channel runs — so channel groups of GROUP_LEN form contiguous K-tiles.
    This is the "reshaped at the granularity of groups" layout from
    Section 4.1/4.4 of the paper.
    """
    n, h, w, c = feat.shape
    fp = jnp.pad(feat, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            window = lax.slice(
                fp,
                (0, i, j, 0),
                (n, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            patches.append(window.reshape(n * oh * ow, c))
    return jnp.concatenate(patches, axis=1)


def kernel2mat(weights: jnp.ndarray) -> jnp.ndarray:
    """Conv weights [KH, KW, C, D] -> GEMM matrix [KH*KW*C, D].

    Row layout matches `im2col`'s K layout: (kh, kw, c), c fastest.
    """
    kh, kw, c, d = weights.shape
    return weights.reshape(kh * kw * c, d)


def conv2d_ref(
    feat: jnp.ndarray,
    weights: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
    relu: bool = False,
) -> jnp.ndarray:
    """Direct NHWC conv2d via lax — the end-to-end oracle for the L2 model.

    `feat`: [N, H, W, C], `weights`: [KH, KW, C, D] -> [N, OH, OW, D].
    """
    out = lax.conv_general_dilated(
        feat.astype(jnp.float32),
        weights.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv2d_im2col_ref(
    feat: jnp.ndarray,
    weights: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
    relu: bool = False,
) -> jnp.ndarray:
    """conv2d computed through the im2col+GEMM path with jnp.matmul.

    This isolates the reshaping logic: it must equal `conv2d_ref`, and the
    Pallas path must equal it in turn.
    """
    n, h, w, _ = feat.shape
    kh, kw, _, d = weights.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    patches = im2col(feat, kh, kw, stride, pad)
    out = gemm_ref(patches, kernel2mat(weights))
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.reshape(n, oh, ow, d)
