"""L1 Pallas kernel variant: direct windowed convolution.

Where `grouped_gemm` computes conv through an explicit im2col (the exact
reshape the Rust compiler performs for ECOO), this kernel keeps the
feature map in its natural NHWC layout and walks the kh x kw taps
*inside* the kernel, accumulating tap-GEMMs over VMEM-resident rows.
This is the CE-array analogy at its sharpest (DESIGN.md
S-Hardware-Adaptation): adjacent output rows reuse overlapping input
rows without re-materializing them — on TPU that overlap lives in VMEM
instead of a CE FIFO chain, and no im2col copies ever exist in HBM.

Grid: one step per (batch, output row). The feature map is passed
un-blocked (whole-array ref) and sliced per tap; outputs are written one
row at a time. interpret=True as always (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, ow: int, relu: bool):
    """Compute one output row: sum over taps of x[row+ky, kx:kx+ow] @ w[ky,kx]."""
    n = pl.program_id(0)
    oy = pl.program_id(1)
    cin = x_ref.shape[3]
    d = w_ref.shape[3]
    acc = jnp.zeros((ow, d), dtype=jnp.float32)
    for ky in range(kh):
        for kx in range(kw):
            # x slice: [ow, cin] window of input row oy+ky starting at kx
            window = x_ref[n, oy + ky, pl.dslice(kx, ow), :]
            tap = w_ref[ky, kx, :, :]
            acc += jnp.dot(
                window.reshape(ow, cin).astype(jnp.float32),
                tap.reshape(cin, d).astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[n, oy, :, :] = acc


def window_conv(
    feat: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    relu: bool = False,
) -> jnp.ndarray:
    """Direct conv2d, stride 1: feat [N,H,W,C] * w [KH,KW,C,D] -> NHWC.

    Padding is applied outside the kernel (zero-pad is free in the ECOO
    view; here it just extends the input rows the taps slide over).
    """
    if pad:
        feat = jnp.pad(feat, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    n, h, wd, c = feat.shape
    kh, kw, c2, d = w.shape
    if c != c2:
        raise ValueError(f"channel mismatch {c} vs {c2}")
    oh = h - kh + 1
    ow = wd - kw + 1
    kernel = functools.partial(_row_kernel, kh=kh, kw=kw, ow=ow, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(n, oh),
        in_specs=[
            # whole-array refs: taps slice them dynamically (the VMEM-
            # resident overlap window)
            pl.BlockSpec(feat.shape, lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, oh, ow, d), lambda i, j: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, d), jnp.float32),
        interpret=True,
    )(feat, w)
