"""L1 Pallas kernel: fused ReLU + symmetric int8 quantization.

This is the inter-layer step of the paper's 8-bit datapath (Section 4.5):
after a convolution, activations pass through ReLU and are re-quantized to
8 bits before being compressed into the ECOO feature flow of the next
layer. ReLU is also where *feature sparsity* is born — every zero this
kernel emits is a token the next layer's DS component will skip — so its
output feeds both the numerics (next conv) and the sparsity statistics the
simulator consumes.

Elementwise, tiled over rows so arbitrary feature-map sizes stream through
a fixed VMEM block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _relu_quant_kernel(x_ref, o_ref, *, scale: float):
    q = jnp.round(jnp.maximum(x_ref[...], 0.0) / scale)
    o_ref[...] = jnp.clip(q, 0, 127).astype(jnp.int8)


def relu_quant(x: jnp.ndarray, scale: float, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """ReLU + symmetric int8 quantize, matching `ref.relu_quant_ref`.

    `x` is flattened to [rows, cols]; rows must tile by `block` after the
    caller's padding (the L2 model always passes group-padded shapes).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    padded = flat.shape[0]
    kernel = functools.partial(_relu_quant_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(padded // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int8),
        interpret=True,
    )(flat)
    return out[:n].reshape(x.shape)
