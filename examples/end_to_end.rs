//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. **L1/L2 (build time)**: `make artifacts` lowered the JAX S2Net —
//!    every conv runs through the Pallas grouped-GEMM kernel — to HLO.
//! 2. **Runtime (PJRT)**: load the artifacts, verify the GEMM numerics
//!    against a Rust oracle, then run real inference: random images +
//!    magnitude-pruned weights -> post-ReLU feature maps with *real*
//!    sparsity.
//! 3. **L3 (simulator)**: feed those real tensors into the compiler +
//!    cycle-accurate S²Engine array, layer by layer, and report the
//!    paper's headline metrics vs the naive dense systolic baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use s2engine::config::{ArrayConfig, FifoDepths, SimConfig};
use s2engine::coordinator::Coordinator;
use s2engine::models::pruning::pruned_weights;
use s2engine::models::tensor::FeatTensor;
use s2engine::models::zoo;
use s2engine::runtime::Runtime;
use s2engine::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = s2engine::runtime::default_artifact_dir();
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!(
                "artifacts not available ({e}); run `make artifacts` first"
            );
            std::process::exit(2);
        }
    };
    println!("== stage 1: PJRT runtime ({} platform)", rt.platform());

    // Numeric contract: the AOT'd Pallas kernel == Rust matmul oracle.
    let err = rt.verify_gemm(7)?;
    println!("   gemm artifact max|err| = {err:.2e}");
    anyhow::ensure!(err < 1e-3, "numeric contract violated");

    // Real inference: random batch + pruned weights.
    let model = zoo::s2net();
    let seed = 42u64;
    let mut rng = Rng::seed_from_u64(seed);
    let c = rt.manifest.cnn.clone();
    let mut image = FeatTensor::zeros(c.batch, c.img_hw, c.img_hw, c.img_c);
    for v in image.data.iter_mut() {
        *v = rng.gen_range_f32(-1.0, 1.0);
    }
    let weights: Vec<_> = c
        .layers
        .iter()
        .zip(&model.layers)
        .map(|(spec, l)| {
            let mut padded = l.clone();
            padded.cin = spec.cin_padded;
            pruned_weights(&padded, model.weight_density, seed)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let feats = rt.run_cnn_features(&image, &weights)?;
    println!(
        "== stage 2: real inference through the Pallas conv stack ({:?})",
        t0.elapsed()
    );
    for (f, spec) in feats.iter().zip(&c.layers) {
        println!(
            "   {:<7} {}x{}x{}x{}  feature density {:.3}",
            spec.name, f.n, f.h, f.w, f.c, f.density()
        );
    }

    // L3: simulate every layer on its REAL input features/weights.
    println!("== stage 3: cycle-accurate S2Engine simulation (real features)");
    let cfg = SimConfig::new(
        ArrayConfig::new(16, 16)
            .with_fifo(FifoDepths::uniform(4))
            .with_ratio(4),
    )
    .with_samples(24)
    .with_seed(seed);
    let coord = Coordinator::new(cfg.clone());
    let scale = 1.0 / 16.0; // quantization scale for feature tokens

    let mut results = Vec::new();
    for (i, l) in model.layers.iter().enumerate() {
        // layer i consumes the PJRT features of layer i-1 (layer 0: the
        // raw image) and the pruned weights actually used above
        let input: FeatTensor = if i == 0 {
            image.clone()
        } else {
            feats[i - 1].clone()
        };
        let mut padded = l.clone();
        padded.cin = c.layers[i].cin_padded;
        let r = coord.simulate_layer_real(&padded, &input, &weights[i], 0, scale);
        println!(
            "   {:<7} fdens {:.2} wdens {:.2}  speedup {:>5.2}x  EE {:>5.2}x  FBred {:>5.2}x",
            l.name,
            r.feature_density,
            r.weight_density,
            r.speedup(),
            r.onchip_ee_improvement(),
            r.buffer_access_reduction()
        );
        results.push(r);
    }

    let model_result = s2engine::coordinator::ModelResult::new(&model, &cfg, results);
    println!("== headline (real-feature S2Net, 16x16, fifo (4,4,4), 4:1)");
    println!("   speedup vs naive systolic : {:.2}x", model_result.speedup());
    println!(
        "   on-chip energy-eff imp.   : {:.2}x",
        model_result.onchip_ee_improvement()
    );
    println!(
        "   energy-eff imp. w/ DRAM   : {:.2}x",
        model_result.total_ee_improvement()
    );
    println!(
        "   area-efficiency imp.      : {:.2}x",
        model_result.area_efficiency_improvement()
    );
    println!(
        "   (paper, ImageNet nets     : ~3.2x speedup, ~3.0x energy, ~2.9x area)"
    );
    anyhow::ensure!(model_result.speedup() > 1.0);
    println!("end_to_end OK");
    Ok(())
}
