//! Sparsity-sensitivity sweep (the Fig. 11 scenario as an API example):
//! run a synthetic AlexNet across feature/weight densities and print how
//! speedup and energy efficiency respond — including the crossover where
//! the dense array wins (the paper's "robustness for different sparsity
//! degrees" claim).
//!
//! ```bash
//! cargo run --release --example sparsity_sweep
//! ```

use s2engine::config::{ArrayConfig, SimConfig};
use s2engine::coordinator::Coordinator;
use s2engine::models::zoo;

fn main() {
    let base = zoo::synthetic_alexnet(1.0, 1.0);
    // keep two representative layers to stay quick
    let mut model = base.clone();
    model.layers = vec![base.layers[1].clone(), base.layers[2].clone()];

    let cfg = SimConfig::new(ArrayConfig::new(16, 16)).with_samples(4);
    let coord = Coordinator::new(cfg);

    println!(
        "{:>9} {:>9} {:>10} {:>10} {:>10}",
        "f-density", "w-density", "speedup", "onchip-EE", "must-MACs"
    );
    let mut crossover_seen = false;
    let mut last_speedup = f64::INFINITY;
    for d in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0] {
        let r = coord.simulate_model_synthetic(&model, d, d);
        let stats = r.total_stats();
        let speedup = r.speedup();
        println!(
            "{:>9.2} {:>9.2} {:>9.2}x {:>9.2}x {:>9.1}%",
            d,
            d,
            speedup,
            r.onchip_ee_improvement(),
            100.0 * stats.mac_ops as f64 / stats.dense_macs as f64
        );
        if speedup < 1.0 {
            crossover_seen = true;
        }
        assert!(
            speedup <= last_speedup * 1.15,
            "speedup should fall (noise-tolerantly) as density rises"
        );
        last_speedup = speedup;
    }
    println!(
        "\ncrossover to dense-wins at high density: {}",
        if crossover_seen { "observed" } else { "not below 1.0 (DS ratio hides it)" }
    );
    println!("sparsity_sweep OK");
}
