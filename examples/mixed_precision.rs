//! Mixed-precision example (Section 4.5 / Fig. 12 / Table IV scenario):
//! promote a fraction of values to split 16-bit tokens and watch the
//! cycle cost respond — the paper's claim is that outlier-aware 16-bit
//! processing on the shared 8-bit datapath costs only ~9-16% extra cycles
//! at a 3.5% outlier ratio.
//!
//! ```bash
//! cargo run --release --example mixed_precision
//! ```

use s2engine::compiler::precision::{decode_mixed, encode_mixed};
use s2engine::config::{ArrayConfig, FifoDepths, SimConfig};
use s2engine::coordinator::Coordinator;
use s2engine::models::zoo;

fn main() {
    // --- token-level demo: a 16-bit outlier splits into two tagged
    //     tokens at the same offset (Fig. 9a) and decodes back exactly.
    let mut group = vec![0i16; 16];
    group[3] = 75; // 8-bit value: 1 token
    group[9] = 4500; // 16-bit outlier: 2 tokens (lo + hi)
    let flow = encode_mixed(&group);
    println!(
        "encoded {} non-zeros into {} tokens (outlier split: {})",
        2,
        flow.tokens.len(),
        flow.tokens.iter().filter(|t| t.tag16()).count()
    );
    assert_eq!(decode_mixed(&flow), group);

    // --- system-level: dense AlexNet-like layer, growing 16-bit ratio.
    let base = zoo::synthetic_alexnet(1.0, 1.0);
    let mut model = base.clone();
    model.layers = vec![base.layers[2].clone()];

    println!(
        "\n{:>12} {:>14} {:>12}",
        "16-bit ratio", "extra cycles", "extra MACs"
    );
    let mk = |ratio16: f64, depth: usize| {
        let array = ArrayConfig::new(16, 16).with_fifo(FifoDepths::uniform(depth));
        let mut cfg = SimConfig::new(array).with_samples(4);
        cfg.ratio16 = ratio16;
        Coordinator::new(cfg).simulate_model_synthetic(&model, 1.0, 1.0)
    };
    let base_run = mk(0.0, 4);
    let base_wall = base_run.total_s2_wall();
    let base_macs = base_run.total_stats().mac_ops as f64;
    let mut prev_extra = -1.0;
    for ratio16 in [0.035, 0.05, 0.10, 0.25] {
        let r = mk(ratio16, 4);
        let extra = r.total_s2_wall() / base_wall - 1.0;
        let extra_macs = r.total_stats().mac_ops as f64 / base_macs - 1.0;
        println!(
            "{:>11.1}% {:>13.1}% {:>11.1}%",
            ratio16 * 100.0,
            extra * 100.0,
            extra_macs * 100.0
        );
        assert!(extra >= prev_extra - 0.02, "cost should grow with ratio");
        prev_extra = extra;
    }

    // deeper FIFOs absorb the split-token burstiness (Table IV's columns)
    let shallow = mk(0.05, 2).total_s2_wall() / mk(0.0, 2).total_s2_wall();
    let deep = mk(0.05, 16).total_s2_wall() / mk(0.0, 16).total_s2_wall();
    println!(
        "\n5% outliers: depth (2,2,2) costs {:.1}% vs depth (16,16,16) {:.1}%",
        (shallow - 1.0) * 100.0,
        (deep - 1.0) * 100.0
    );
    assert!(deep <= shallow + 0.02);
    println!("mixed_precision OK");
}
