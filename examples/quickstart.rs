//! Quickstart: simulate one sparse conv layer on S²Engine and compare it
//! against the naive dense systolic array — the 60-second tour of the
//! public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use s2engine::config::{ArrayConfig, FifoDepths, SimConfig};
use s2engine::coordinator::Coordinator;
use s2engine::models::zoo;

fn main() {
    // 1. Pick a workload: AlexNet conv3 at the paper's Table II sparsity.
    let model = zoo::alexnet();
    let layer = model.layer("conv3").unwrap();
    println!(
        "workload: {} {}x{}x{} * {}x{}x{}x{} ({} dense MACs)",
        layer.name, layer.in_h, layer.in_w, layer.cin, layer.kh, layer.kw,
        layer.cin, layer.cout, layer.macs()
    );

    // 2. Configure the array: 16x16 PEs, (4,4,4) FIFOs, DS at 4x MAC clock.
    let cfg = SimConfig::new(
        ArrayConfig::new(16, 16)
            .with_fifo(FifoDepths::uniform(4))
            .with_ratio(4),
    )
    .with_samples(8);

    // 3. Simulate: the coordinator compiles the layer into ECOO dataflows,
    //    runs the cycle-accurate array on a tile sample, and extrapolates.
    let coord = Coordinator::new(cfg);
    let r = coord.simulate_layer(
        layer,
        model.feature_density,
        model.weight_density,
        true, // clustered non-zeros, like real feature maps
    );

    // 4. Read the results.
    println!("S2Engine DS cycles : {}", r.s2.ds_cycles);
    println!("naive MAC cycles   : {}", r.naive.mac_cycles);
    println!(
        "MACs performed     : {} of {} dense ({:.1}% skipped)",
        r.s2.mac_ops,
        r.naive.mac_ops,
        100.0 * r.s2.skip_ratio()
    );
    println!("speedup            : {:.2}x", r.speedup());
    println!("on-chip EE imp.    : {:.2}x", r.onchip_ee_improvement());
    println!(
        "FB access reduction: {:.2}x (CE array overlap reuse)",
        r.buffer_access_reduction()
    );

    assert!(r.speedup() > 1.0, "sparsity must beat the dense array");
}
