//! User-defined design-space exploration on the sweep engine: declare a
//! custom grid (the kind of study the paper never ran), execute it with
//! a resumable store, then re-run to show that every point is served
//! from the store.
//!
//! The same study from the CLI:
//!
//! ```bash
//! s2engine sweep --grid 'models=alexnet,resnet50;scales=8,16;fifos=2,inf' \
//!                --out /tmp/dse --resume
//! ```
//!
//! ```bash
//! cargo run --release --example dse_sweep
//! ```

use s2engine::report::Effort;
use s2engine::sweep::{Grid, Runner, Store};

fn main() {
    // Small rectangular arrays vs the paper's squares: does a wide
    // 8x16 beat a square 16x16 per unit area at AlexNet sparsity?
    let effort = Effort {
        tile_samples: 2,
        layer_stride: 3,
        images: 0,
    };
    let grid = Grid::new(effort, 0x5eed)
        .models(&["alexnet", "resnet50"])
        .scales(&[(8, 8), (8, 16), (16, 16)])
        .fifos(&[
            s2engine::config::FifoDepths::uniform(4),
            s2engine::config::FifoDepths::infinite(),
        ]);
    let plan = grid.plan();
    println!("declared {} sweep points\n", plan.len());

    let dir = std::env::temp_dir().join(format!("s2-dse-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let store_path = dir.join("sweep.jsonl");

    let mut store = Store::open(&store_path, false).unwrap();
    let res = Runner::new().run(&plan, &mut store);
    println!(
        "{:<10} {:>6} {:>12} {:>9} {:>9} {:>9}",
        "model", "array", "fifo", "speedup", "EE imp", "AE imp"
    );
    for rec in res.records() {
        let j = &rec.job;
        println!(
            "{:<10} {:>2}x{:<3} {:>12} {:>8.2}x {:>8.2}x {:>8.2}x",
            j.model,
            j.array.rows,
            j.array.cols,
            j.array.fifo.label(),
            rec.speedup,
            rec.onchip_ee,
            rec.area_eff,
        );
    }
    assert_eq!(res.ran, plan.len());

    // a second run resumes entirely from the store
    let mut store = Store::open(&store_path, true).unwrap();
    let resumed = Runner::new().run(&plan, &mut store);
    assert_eq!(resumed.ran, 0);
    assert_eq!(resumed.reused, plan.len());
    assert_eq!(res.records(), resumed.records());
    println!(
        "\nresumed run: {} simulated, {} served from {}",
        resumed.ran,
        resumed.reused,
        store_path.display()
    );
    std::fs::remove_dir_all(&dir).ok();
    println!("dse_sweep OK");
}
