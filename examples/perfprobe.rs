//! Perf probe for the simulator hot loop (EXPERIMENTS.md §Perf): measures
//! PE-cycle-step throughput of `simulate_tile` on a VGG-class tile,
//! best-of-6 chunks to ride out scheduler noise on small machines.
//!
//! ```bash
//! cargo run --release --example perfprobe
//! ```

use s2engine::compiler::mapping::{build_tile, LayerMapping, TileSource};
use s2engine::config::ArrayConfig;
use s2engine::models::LayerDesc;
use s2engine::sim::simulate_tile;

fn main() {
    let layer = LayerDesc::new("vggish", 28, 28, 256, 3, 3, 256, 1, 1);
    let mapping = LayerMapping::new(&layer, 16, 16);
    let src = TileSource::Synthetic {
        feature_density: 0.35,
        weight_density: 0.35,
        clustered: true,
    };
    let tile = build_tile(&mapping, mapping.n_col_tiles() + 1, &src, 0.0, 7);
    let cfg = ArrayConfig::new(16, 16);
    for _ in 0..5 {
        std::hint::black_box(simulate_tile(&tile, &cfg, true));
    }
    let mut best = f64::MAX;
    for _ in 0..6 {
        let t = std::time::Instant::now();
        let mut cycles = 0u64;
        for _ in 0..20 {
            cycles += simulate_tile(&tile, &cfg, true).ds_cycles;
        }
        let el = t.elapsed().as_secs_f64();
        eprint!("{:.1} ", cycles as f64 * 256.0 / el / 1e6);
        best = best.min(el);
    }
    let cycles20 = 20 * simulate_tile(&tile, &cfg, true).ds_cycles;
    println!(
        "\nBEST: {:.1} M PE-steps/s",
        cycles20 as f64 * 256.0 / best / 1e6
    );
}
