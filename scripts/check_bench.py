#!/usr/bin/env python3
"""Validate the BENCH_*.json files the bench targets emit.

CI's non-blocking bench-smoke job runs every bench target in quick mode
(BENCH_QUICK=1) and then calls this script on the resulting JSONs. The
check fails ONLY on malformed documents or missing metric keys — never
on the measured values themselves: timings in CI are noisy, and perf
*gating* is deferred until a recorded trajectory exists to gate against.

Checked per file (the `util::bench::Bench::to_json` schema):
  * top level is an object with `benches` and `metrics` objects;
  * every bench entry carries numeric `mean_ns`/`p50_ns`/`min_ns`/
    `std_dev_ns`/`iters`, with `mean_ns` and `iters` positive and
    `min_ns <= mean_ns`;
  * every metric entry carries a finite numeric `value` and a string
    `unit`;
  * known files additionally carry their headline metric keys (by
    prefix, since some names are parameterized) — see REQUIRED below.

Usage: python3 scripts/check_bench.py [BENCH_foo.json ...]
With no arguments, checks every BENCH_*.json in the current directory.
Exits nonzero listing every violation.
"""

import glob
import json
import math
import os
import sys

BENCH_FIELDS = ("mean_ns", "p50_ns", "min_ns", "std_dev_ns", "iters")

# headline metric-name prefixes each known file must carry; a bench
# binary that silently stops reporting its key metric fails the smoke
# check even though it still times something
REQUIRED = {
    "BENCH_sim.json": ["sim/event-vs-sweep speedup"],
    "BENCH_serve.json": [
        "model/pipeline-gain",
        "model/throughput-b1",
        "model/sim-reqs-per-s-r1e6",
        "model/fastpath-speedup-r1e6",
    ],
    "BENCH_serve_scale.json": [
        "scale/fastpath-speedup-r1e3",
        "scale/fastpath-speedup-r1e4",
        "scale/fastpath-speedup-r1e6",
        "scale/sim-reqs-per-s-r1e6",
        "scale/steady-gain-r1e6",
    ],
    "BENCH_cluster.json": [
        "model/scaleout-eff-data-n4",
        "model/scaleout-eff-pipeline-n4",
        "model/scaleout-eff-tensor-n4",
        "model/link-traffic-tensor-n4",
    ],
    "BENCH_backends.json": [
        "model/speedup-s2",
        "model/speedup-naive",
        "model/speedup-scnn",
        "model/speedup-sparten",
        "model/onchip-ee-sparten",
        "model/throughput-s2-b4",
    ],
    "BENCH_sweep.json": ["sweep/jobs"],
    "BENCH_traffic.json": [
        "traffic/sim-reqs-per-s-poisson-r1e6",
        "traffic/slo-overhead-r1e6",
        "pareto/min-arrays-at-slo",
    ],
    "BENCH_cluster_chaos.json": [
        "model/makespan-inflation-data-n4",
        "model/makespan-inflation-pipeline-n4",
        "model/makespan-inflation-tensor-n4",
        "model/retries-data-n4",
        "model/bound-slack-tensor-n4",
    ],
}


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_file(path):
    errors = []
    err = lambda msg: errors.append(f"{path}: {msg}")
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/malformed JSON ({e})"]

    if not isinstance(doc, dict):
        return [f"{path}: top level is not an object"]
    benches = doc.get("benches")
    metrics = doc.get("metrics")
    if not isinstance(benches, dict):
        err("missing/invalid `benches` object")
        benches = {}
    if not isinstance(metrics, dict):
        err("missing/invalid `metrics` object")
        metrics = {}
    if not benches and not metrics:
        err("carries neither timings nor metrics")

    for name, b in benches.items():
        if not isinstance(b, dict):
            err(f"bench `{name}` is not an object")
            continue
        for field in BENCH_FIELDS:
            if not is_num(b.get(field)):
                err(f"bench `{name}` missing numeric `{field}`")
        if is_num(b.get("mean_ns")) and b["mean_ns"] <= 0:
            err(f"bench `{name}` has non-positive mean_ns")
        if is_num(b.get("iters")) and b["iters"] < 1:
            err(f"bench `{name}` has iters < 1")
        if (
            is_num(b.get("min_ns"))
            and is_num(b.get("mean_ns"))
            and b["min_ns"] > b["mean_ns"]
        ):
            err(f"bench `{name}` has min_ns > mean_ns")

    for name, m in metrics.items():
        if not isinstance(m, dict):
            err(f"metric `{name}` is not an object")
            continue
        v = m.get("value")
        if not is_num(v) or not math.isfinite(v):
            err(f"metric `{name}` missing finite numeric `value`")
        if not isinstance(m.get("unit"), str):
            err(f"metric `{name}` missing string `unit`")

    for prefix in REQUIRED.get(os.path.basename(path), []):
        if not any(name.startswith(prefix) for name in metrics):
            err(f"missing required metric `{prefix}*`")

    return errors


def main(argv):
    paths = argv[1:] or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failures = []
    for path in paths:
        errs = check_file(path)
        if errs:
            failures.extend(errs)
        else:
            print(f"check_bench: {path} OK")
    for msg in failures:
        print(f"check_bench: FAIL {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
