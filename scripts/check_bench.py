#!/usr/bin/env python3
"""Validate the BENCH_*.json files the bench targets emit.

CI's non-blocking bench-smoke job runs every bench target in quick mode
(BENCH_QUICK=1) and then calls this script on the resulting JSONs. The
check fails ONLY on malformed documents or missing metric keys — never
on the measured values themselves: timings in CI are noisy, and perf
*gating* is deferred until a recorded trajectory exists to gate against.

Checked per file (the `util::bench::Bench::to_json` schema):
  * top level is an object with `benches` and `metrics` objects;
  * every bench entry carries numeric `mean_ns`/`p50_ns`/`min_ns`/
    `std_dev_ns`/`iters`, with `mean_ns` and `iters` positive and
    `min_ns <= mean_ns`;
  * every metric entry carries a finite numeric `value` and a string
    `unit`;
  * known files additionally carry their headline metric keys (by
    prefix, since some names are parameterized) — see REQUIRED below.

Usage: python3 scripts/check_bench.py [BENCH_foo.json ...]
With no arguments, checks every BENCH_*.json in the current directory.
Exits nonzero listing every violation.

Regression mode: `--compare BASELINE_DIR [--tol PCT]` additionally
diffs every file's *metrics* (never the nanosecond timings — those are
runner-noise) against the same-named file in BASELINE_DIR. A metric
whose relative change from baseline exceeds PCT percent (default 10)
is reported as DRIFT and fails the check; metrics new since the
baseline are informational; metrics that *disappeared* fail. A file
with no baseline counterpart — or an empty/missing baseline directory,
the state before the first snapshot is recorded — is skipped with a
notice, so the compare step degrades gracefully until a baseline
exists (see BENCH_baseline/README.md for the snapshot protocol).
"""

import glob
import json
import math
import os
import sys

BENCH_FIELDS = ("mean_ns", "p50_ns", "min_ns", "std_dev_ns", "iters")

# headline metric-name prefixes each known file must carry; a bench
# binary that silently stops reporting its key metric fails the smoke
# check even though it still times something
REQUIRED = {
    "BENCH_sim.json": ["sim/event-vs-sweep speedup"],
    "BENCH_serve.json": [
        "model/pipeline-gain",
        "model/throughput-b1",
        "model/sim-reqs-per-s-r1e6",
        "model/fastpath-speedup-r1e6",
    ],
    "BENCH_serve_scale.json": [
        "scale/fastpath-speedup-r1e3",
        "scale/fastpath-speedup-r1e4",
        "scale/fastpath-speedup-r1e6",
        "scale/sim-reqs-per-s-r1e6",
        "scale/steady-gain-r1e6",
        "model/dyn-sim-reqs-per-s-r1e6",
        "model/dyn-fastpath-speedup-r1e6",
    ],
    "BENCH_cluster.json": [
        "model/scaleout-eff-data-n4",
        "model/scaleout-eff-pipeline-n4",
        "model/scaleout-eff-tensor-n4",
        "model/link-traffic-tensor-n4",
    ],
    "BENCH_backends.json": [
        "model/speedup-s2",
        "model/speedup-naive",
        "model/speedup-scnn",
        "model/speedup-sparten",
        "model/onchip-ee-sparten",
        "model/throughput-s2-b4",
    ],
    "BENCH_sweep.json": ["sweep/jobs"],
    "BENCH_traffic.json": [
        "traffic/sim-reqs-per-s-poisson-r1e6",
        "traffic/slo-overhead-r1e6",
        "pareto/min-arrays-at-slo",
    ],
    "BENCH_cluster_chaos.json": [
        "model/makespan-inflation-data-n4",
        "model/makespan-inflation-pipeline-n4",
        "model/makespan-inflation-tensor-n4",
        "model/retries-data-n4",
        "model/bound-slack-tensor-n4",
    ],
}


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_file(path):
    errors = []
    err = lambda msg: errors.append(f"{path}: {msg}")
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/malformed JSON ({e})"]

    if not isinstance(doc, dict):
        return [f"{path}: top level is not an object"]
    benches = doc.get("benches")
    metrics = doc.get("metrics")
    if not isinstance(benches, dict):
        err("missing/invalid `benches` object")
        benches = {}
    if not isinstance(metrics, dict):
        err("missing/invalid `metrics` object")
        metrics = {}
    if not benches and not metrics:
        err("carries neither timings nor metrics")

    for name, b in benches.items():
        if not isinstance(b, dict):
            err(f"bench `{name}` is not an object")
            continue
        for field in BENCH_FIELDS:
            if not is_num(b.get(field)):
                err(f"bench `{name}` missing numeric `{field}`")
        if is_num(b.get("mean_ns")) and b["mean_ns"] <= 0:
            err(f"bench `{name}` has non-positive mean_ns")
        if is_num(b.get("iters")) and b["iters"] < 1:
            err(f"bench `{name}` has iters < 1")
        if (
            is_num(b.get("min_ns"))
            and is_num(b.get("mean_ns"))
            and b["min_ns"] > b["mean_ns"]
        ):
            err(f"bench `{name}` has min_ns > mean_ns")

    for name, m in metrics.items():
        if not isinstance(m, dict):
            err(f"metric `{name}` is not an object")
            continue
        v = m.get("value")
        if not is_num(v) or not math.isfinite(v):
            err(f"metric `{name}` missing finite numeric `value`")
        if not isinstance(m.get("unit"), str):
            err(f"metric `{name}` missing string `unit`")

    for prefix in REQUIRED.get(os.path.basename(path), []):
        if not any(name.startswith(prefix) for name in metrics):
            err(f"missing required metric `{prefix}*`")

    return errors


def load_metrics(path):
    """The `metrics` object of a bench JSON, {} when absent/unreadable."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    metrics = doc.get("metrics") if isinstance(doc, dict) else None
    return metrics if isinstance(metrics, dict) else {}


def compare_file(path, baseline_dir, tol_pct):
    """Diff `path`'s metrics against the same-named baseline file.
    Returns (errors, notes): errors fail the check, notes are
    informational."""
    errors, notes = [], []
    base_path = os.path.join(baseline_dir, os.path.basename(path))
    if not os.path.exists(base_path):
        notes.append(f"{path}: no baseline at {base_path} (skipped)")
        return errors, notes
    base = load_metrics(base_path)
    cur = load_metrics(path)
    if not base:
        notes.append(f"{path}: baseline {base_path} carries no metrics (skipped)")
        return errors, notes
    for name, bm in sorted(base.items()):
        bv = bm.get("value") if isinstance(bm, dict) else None
        if not is_num(bv) or not math.isfinite(bv):
            continue
        cm = cur.get(name)
        if not isinstance(cm, dict):
            errors.append(f"{path}: metric `{name}` disappeared since baseline")
            continue
        cv = cm.get("value")
        if not is_num(cv) or not math.isfinite(cv):
            errors.append(f"{path}: metric `{name}` no longer finite")
            continue
        denom = max(abs(bv), 1e-300)
        change = (cv - bv) / denom * 100.0
        if abs(change) > tol_pct:
            errors.append(
                f"{path}: DRIFT `{name}` {bv:g} -> {cv:g} "
                f"({change:+.1f}%, tol {tol_pct:g}%)"
            )
        else:
            notes.append(f"{path}: `{name}` {bv:g} -> {cv:g} ({change:+.1f}%)")
    for name in sorted(set(cur) - set(base)):
        notes.append(f"{path}: metric `{name}` is new since baseline")
    return errors, notes


def main(argv):
    args = argv[1:]
    baseline_dir = None
    tol_pct = 10.0
    paths = []
    i = 0
    while i < len(args):
        if args[i] == "--compare":
            i += 1
            if i == len(args):
                print("check_bench: --compare needs a directory", file=sys.stderr)
                return 2
            baseline_dir = args[i]
        elif args[i] == "--tol":
            i += 1
            if i == len(args):
                print("check_bench: --tol needs a percentage", file=sys.stderr)
                return 2
            try:
                tol_pct = float(args[i])
            except ValueError:
                print(f"check_bench: bad --tol '{args[i]}'", file=sys.stderr)
                return 2
            if not math.isfinite(tol_pct) or tol_pct < 0:
                print(f"check_bench: bad --tol '{args[i]}'", file=sys.stderr)
                return 2
        else:
            paths.append(args[i])
        i += 1
    paths = paths or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failures = []
    for path in paths:
        errs = check_file(path)
        if errs:
            failures.extend(errs)
        else:
            print(f"check_bench: {path} OK")
        if baseline_dir is not None:
            cerrs, notes = compare_file(path, baseline_dir, tol_pct)
            for msg in notes:
                print(f"check_bench: {msg}")
            failures.extend(cerrs)
    for msg in failures:
        print(f"check_bench: FAIL {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
