#!/usr/bin/env python3
"""Line-for-line Python transcription of rust/src/cluster/schedule.rs
(`data_parallel`, the chain case of `layer_pipeline`, `tensor_shard`)
and rust/src/cluster/shard.rs (`balanced_stages`, the link model),
fuzzed against the invariants `rust/tests/cluster_equivalence.rs`
enforces in CI:

  * every strategy at arrays = 1 is EXACTLY the single-array pipeline
    (same makespan / finish times / busy — same float ops, same bits);
  * DataParallel makespan is monotone non-increasing in the array count
    under closed-loop load (every request queued at t = 0);
  * per-strategy makespan >= critical path + mandatory transfer time
    (TensorShard's gather rides inside its effective durations);
  * per-replica/stage busy never exceeds the cluster makespan; every
    request's completion respects its own chain + transfers.

Also transcribed here: the cluster-realism chaos engine
(rust/src/cluster/event.rs — heterogeneous fleets, seeded failures and
stragglers, epoch re-sharding with retry), its RNG plumbing
(rust/src/util/rng.rs xoshiro256++/SplitMix64, rust/src/serve/engine.rs
`exp_interval` + `EventQueue`), `apportion`, and
`balanced_stages_weighted` (rust/src/cluster/shard.rs). The chaos fuzz
enforces: exactly-once completion under any failure trajectory,
makespan >= the generalized (fastest-array / full-capacity) lower
bound, bit-level determinism per seed, failure/straggler stream
decorrelation, single-epoch degeneracy when chaos is off, and
unit-speed equivalence of the weighted stage cutter — and replays the
exact inputs of the Rust unit tests in rust/src/cluster/event.rs so
those assertions are pre-verified here.

The single-array scheduler transcription is imported from
scripts/fuzz_serve_pipeline.py (kept in sync with serve/pipeline.rs).
Run `python3 scripts/fuzz_cluster.py`; exits nonzero with the offending
configuration on any violation. Keep this file in sync with
rust/src/cluster/ when touching scheduler semantics (see
.claude/skills/verify/SKILL.md).
"""

import heapq
import math
import os
import random
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from fuzz_serve_pipeline import build, critical_path_chain, topo_chain  # noqa: E402

LINK_BYTES_PER_S = 25.0e9


def link_seconds(b):
    return b / LINK_BYTES_PER_S


def chain_build(durations, arrivals, batch, overlap):
    n = len(durations)
    topo, deps = topo_chain(n)
    return build(n, deps, topo, durations, arrivals, batch, overlap, [n - 1])


def balanced_stages(durations, n):
    """Transcription of shard::balanced_stages."""
    ln = len(durations)
    stages = min(max(n, 1), max(ln, 1))
    if ln == 0:
        return [0]
    total = 0.0
    for d in durations:
        total = total + d
    longest = 0.0
    for d in durations:
        longest = max(longest, d)

    def cut(cap):
        ends = []
        acc = 0.0
        for i, d in enumerate(durations):
            if acc > 0.0 and acc + d > cap:
                ends.append(i)
                acc = 0.0
            acc += d
        ends.append(ln)
        return ends

    lo, hi = longest, total
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if len(cut(mid)) <= stages:
            hi = mid
        else:
            lo = mid
    ends = cut(hi)
    while len(ends) > stages:
        last = ends.pop()
        ends[-1] = last
    return ends


def data_parallel(durations, arrivals, batch, overlap, arrays):
    """Transcription of schedule::data_parallel (chain DAG)."""
    arrays = max(arrays, 1)
    member = [[] for _ in range(arrays)]
    for i in range(len(arrivals)):
        member[i % arrays].append(i)
    lanes = []
    finish_times = [0.0] * len(arrivals)
    makespan = 0.0
    for requests in member:
        sub = [arrivals[i] for i in requests]
        jobs, ft, m, busy = chain_build(durations, sub, batch, overlap)
        for slot, i in enumerate(requests):
            finish_times[i] = ft[slot]
        makespan = max(makespan, m)
        lanes.append((busy, len(jobs)))
    lower = max((a + critical_path_chain(durations) for a in arrivals), default=0.0)
    return lanes, finish_times, makespan, 0.0, lower


def layer_pipeline(durations, out_bytes, arrivals, batch, overlap, arrays):
    """Transcription of schedule::layer_pipeline for a chain DAG (the
    zoo topology): each stage is a contiguous sub-chain, and the only
    edge into stage s is from the last node of stage s-1."""
    arrays = max(arrays, 1)
    ends = balanced_stages(durations, arrays)
    if len(ends) == 1:
        jobs, ft, m, busy = chain_build(durations, arrivals, batch, overlap)
        lanes = [(0.0, 0)] * arrays
        lanes[0] = (busy, len(jobs))
        lower = max(
            (a + critical_path_chain(durations) for a in arrivals), default=0.0
        )
        return lanes, ft, m, 0.0, lower
    lanes = [(0.0, 0)] * arrays
    makespan = 0.0
    mandatory = 0.0
    stage_arrivals = list(arrivals)
    finish_times = list(arrivals)
    lo = 0
    for s, hi in enumerate(ends):
        if s > 0:
            moved = out_bytes[lo - 1]  # chain: one boundary producer
            t = link_seconds(moved)
            mandatory += t
            stage_arrivals = [f + t for f in finish_times]
        # build() requires a sorted arrival timeline; downstream stages
        # must inherit sortedness from the finish-time ordering (the
        # Rust side debug_asserts the same property)
        assert all(
            a <= b for a, b in zip(stage_arrivals, stage_arrivals[1:])
        ), (s, stage_arrivals)
        sub_durs = durations[lo:hi]
        jobs, ft, m, busy = chain_build(sub_durs, stage_arrivals, batch, overlap)
        lanes[s] = (busy, len(jobs))
        makespan = max(makespan, m)
        finish_times = ft
        lo = hi
    lower = max(
        (a + critical_path_chain(durations) + mandatory for a in arrivals),
        default=0.0,
    )
    return lanes, finish_times, makespan, mandatory, lower


def tensor_shard(durations, tiles, out_bytes, arrivals, batch, overlap, arrays):
    """Transcription of schedule::tensor_shard (chain DAG)."""
    arrays = max(arrays, 1)
    n = float(arrays)
    mandatory = 0.0
    d_sched = []
    for d, t, b in zip(durations, tiles, out_bytes):
        share = 1.0 if t == 0 else (-(-t // arrays)) / t
        if arrays > 1:
            gather = link_seconds(b) * (n - 1.0) / n
        else:
            gather = 0.0
        mandatory += gather
        d_sched.append(d * share + gather)
    jobs, ft, m, busy = chain_build(d_sched, arrivals, batch, overlap)
    lanes = [(busy, len(jobs))] * arrays
    lower = max((a + critical_path_chain(d_sched) for a in arrivals), default=0.0)
    return lanes, ft, m, mandatory, lower


# ---------------------------------------------------------------------------
# Chaos-engine transcription: rust/src/cluster/event.rs, the RNG plumbing
# it draws from (rust/src/util/rng.rs, rust/src/serve/engine.rs), and the
# heterogeneity-aware stage cutter (rust/src/cluster/shard.rs).
# ---------------------------------------------------------------------------

MASK = (1 << 64) - 1
FAIL_SALT = 0xFA110F5E
STRAGGLE_SALT = 0x57A61E0B
MAX_EPOCHS = 10_000
INF = float("inf")
STRATS = ("data", "pipeline", "tensor")
# chaos tuples are (mtbf, mttr, straggle_p, straggle_factor)
CHAOS_OFF = (INF, 0.0, 0.0, 1.0)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, z ^ (z >> 31)


class Rng:
    """Transcription of util::rng::Rng (xoshiro256++, SplitMix64-seeded)."""

    def __init__(self, seed):
        st = seed & MASK
        s = []
        for _ in range(4):
            st, v = _splitmix64(st)
            s.append(v)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def gen_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


def hash_seed(seed, name):
    """Transcription of util::rng::hash_seed (FNV-1a mixed with a seed)."""
    h = 0xCBF29CE484222325 ^ (seed & MASK)
    for b in name.encode():
        h ^= b
        h = (h * 0x100000001B3) & MASK
    return h


def exp_interval(rng, rate):
    """Transcription of serve::engine::exp_interval."""
    if not (rate > 0.0) or not math.isfinite(rate):
        return INF
    return -math.log(1.0 - rng.gen_f64()) / rate


class EventQueue:
    """serve::engine::EventQueue equivalent: strict min-heap on
    (time, seq) with FIFO ties. The Rust side hand-rolls the heap but
    both pop the unique global (time, seq) minimum, so the observable
    event sequence is identical (times are never NaN here)."""

    def __init__(self):
        self.heap = []
        self.seq = 0

    def push(self, time, item):
        heapq.heappush(self.heap, (time, self.seq, item))
        self.seq += 1

    def peek_time(self):
        return self.heap[0][0] if self.heap else None

    def pop(self):
        if not self.heap:
            return None
        t, _, item = heapq.heappop(self.heap)
        return t, item


def apportion(total, weights):
    """Transcription of cluster::event::apportion (largest remainder).
    Rust's `Iterator::max_by` returns the LAST maximal element, hence
    the (share, index) key in the defensive trim."""
    k = len(weights)
    if k == 0:
        return []
    w_sum = 0.0
    for w in weights:
        w_sum += w
    if not (w_sum > 0.0):
        out = [0] * k
        out[0] = total
        return out
    quotas = [total * w / w_sum for w in weights]
    shares = [int(math.floor(q)) for q in quotas]
    assigned = sum(shares)
    while assigned > total:
        i = max(range(k), key=lambda j: (shares[j], j))
        shares[i] -= 1
        assigned -= 1
    order = sorted(range(k), key=lambda j: (-(quotas[j] - shares[j]), j))
    for i in range(total - assigned):
        shares[order[i % k]] += 1
    return shares


def balanced_stages_weighted(durations, speeds):
    """Transcription of shard::balanced_stages_weighted."""
    ln = len(durations)
    n = max(len(speeds), 1)
    if ln == 0:
        return [0]
    if n == 1:
        return [ln]

    def speed(s):
        v = speeds[s] if s < len(speeds) else 1.0
        return v if (v > 0.0 and math.isfinite(v)) else 1.0

    total_work = sum(durations)
    min_speed = INF
    for s in range(n):
        min_speed = min(min_speed, speed(s))
    longest = 0.0
    for d in durations:
        longest = max(longest, d)

    def cut(cap):
        ends = []
        acc = 0.0
        stage = 0
        for i, d in enumerate(durations):
            if acc > 0.0 and acc + d > cap * speed(min(stage, n - 1)):
                ends.append(i)
                acc = 0.0
                stage += 1
            acc += d
        ends.append(ln)
        return ends

    max_speed = 0.0
    for s in range(n):
        max_speed = max(max_speed, speed(s))
    lo, hi = longest / max_speed, total_work / min_speed
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if len(cut(mid)) <= n:
            hi = mid
        else:
            lo = mid
    ends = cut(hi)
    while len(ends) > n:
        last = ends.pop()
        ends[-1] = last
    return ends


def chaos_has_failures(chaos):
    return math.isfinite(chaos[0]) and chaos[0] > 0.0


def chaos_has_stragglers(chaos):
    return chaos[2] > 0.0 and chaos[3] > 1.0


def apply_transition(ev, at, chaos, up, down_since, fail_rng, queue, stats):
    kind, i = ev
    mtbf, mttr = chaos[0], chaos[1]
    if kind == "down":
        up[i] = False
        down_since[i] = at
        stats["failures"] += 1
        repair = exp_interval(fail_rng[i], 1.0 / mttr) if mttr > 0.0 else 0.0
        queue.push(at + repair, ("up", i))
    else:
        up[i] = True
        stats["recoveries"] += 1
        stats["downtime"] += at - down_since[i]
        queue.push(at + exp_interval(fail_rng[i], 1.0 / mtbf), ("down", i))


def epoch_data_parallel(durations, arrivals, pending, live, speeds, t, epoch_end):
    chain = sum(durations)
    n_layers = len(durations)
    load = [t] * len(live)
    out = []
    for r in pending:
        arr = max(arrivals[r], t)
        if arr >= epoch_end:
            break  # clamped arrivals are sorted: the rest wait too
        best = 0
        best_finish = INF
        for k in range(len(live)):
            f = max(load[k], arr) + chain / speeds[k]
            if f < best_finish:
                best_finish = f
                best = k
        start = max(load[best], arr)
        finish = start + chain / speeds[best]
        load[best] = finish
        out.append(
            {
                "req": r,
                "start": start,
                "finish": finish,
                "lanes": [(live[best], chain / speeds[best], n_layers)],
                "bytes": 0.0,
            }
        )
    return out


def epoch_layer_pipeline(
    durations, out_bytes, arrivals, pending, live, speeds, t, epoch_end
):
    ends = balanced_stages_weighted(durations, speeds)
    n_stages = len(ends)
    stage_time = []
    stage_layers = []
    transfer = []
    bytes_per_req = 0.0
    lo = 0
    for s, hi in enumerate(ends):
        work = sum(durations[lo:hi])
        stage_time.append(work / speeds[min(s, len(speeds) - 1)])
        stage_layers.append(hi - lo)
        if s > 0 and lo > 0:
            transfer.append(link_seconds(out_bytes[lo - 1]))
            bytes_per_req += out_bytes[lo - 1]
        else:
            transfer.append(0.0)
        lo = hi
    stage_free = [t] * n_stages
    out = []
    for r in pending:
        arr = max(arrivals[r], t)
        if arr >= epoch_end:
            break
        start = max(stage_free[0], arr)
        f = start + stage_time[0]
        stage_free[0] = f
        lanes = [(live[0], stage_time[0], stage_layers[0])]
        for s in range(1, n_stages):
            ready = f + transfer[s]
            f = max(stage_free[s], ready) + stage_time[s]
            stage_free[s] = f
            lanes.append((live[s], stage_time[s], stage_layers[s]))
        out.append(
            {
                "req": r,
                "start": start,
                "finish": f,
                "lanes": lanes,
                "bytes": bytes_per_req,
            }
        )
    return out


def epoch_tensor_shard(
    durations, tiles, out_bytes, arrivals, pending, live, speeds, fleet, t, epoch_end
):
    k = len(live)
    m = float(k)
    weights = [s * fleet[i][1] for i, s in zip(live, speeds)]
    per_lane = [0.0] * k
    service = 0.0
    gather_total = 0.0
    bytes_per_req = 0.0
    for d, tl, b in zip(durations, tiles, out_bytes):
        layer_t = 0.0
        if tl == 0:
            # no tile grid to split: every shard runs the full layer
            for kk, s in enumerate(speeds):
                w = d / s
                per_lane[kk] += w
                layer_t = max(layer_t, w)
        else:
            shares = apportion(tl, weights)
            for kk, s in enumerate(speeds):
                w = d * (shares[kk] / tl) / s
                per_lane[kk] += w
                layer_t = max(layer_t, w)
        if k > 1:
            bytes_per_req += b * (m - 1.0)
            gather = link_seconds(b) * (m - 1.0) / m
        else:
            gather = 0.0
        gather_total += gather
        service += layer_t + gather
    n_layers = len(durations)
    free = t
    out = []
    for r in pending:
        arr = max(arrivals[r], t)
        if arr >= epoch_end:
            break
        start = max(free, arr)
        finish = start + service
        free = finish
        lanes = [(live[kk], per_lane[kk] + gather_total, n_layers) for kk in range(k)]
        out.append(
            {
                "req": r,
                "start": start,
                "finish": finish,
                "lanes": lanes,
                "bytes": bytes_per_req,
            }
        )
    return out


def run_chaos(strategy, durations, tiles, out_bytes, arrivals, fleet, chaos, seed):
    """Transcription of cluster::event::run_chaos. `fleet` is a list of
    (speed, size) tuples; `chaos` is (mtbf, mttr, p, factor)."""
    n = max(len(fleet), 1)
    fleet = list(fleet) if fleet else [(1.0, 1.0)]
    n_req = len(arrivals)
    chain = sum(durations)
    mtbf, _mttr, straggle_p, straggle_factor = chaos

    max_speed = 0.0
    for sp, _sz in fleet:
        max_speed = max(max_speed, sp)
    total_speed = 0.0
    for sp, _sz in fleet:
        total_speed += sp
    if strategy in ("data", "pipeline"):
        floor = chain / max_speed
    else:
        floor = chain / total_speed
    lower_bound = 0.0
    for a in arrivals:
        lower_bound = max(lower_bound, a + floor)

    full_speeds = [sp for sp, _sz in fleet]
    if strategy == "data":
        mandatory = 0.0
    elif strategy == "pipeline":
        ends = balanced_stages_weighted(durations, full_speeds)
        mandatory = 0.0
        lo = 0
        for s, hi in enumerate(ends):
            if s > 0 and lo > 0:
                mandatory += link_seconds(out_bytes[lo - 1])
            lo = hi
    else:
        if n > 1:
            m = float(n)
            mandatory = 0.0
            for b in out_bytes:
                mandatory += link_seconds(b) * (m - 1.0) / m
        else:
            mandatory = 0.0

    fail_rng = [Rng(hash_seed(seed ^ FAIL_SALT, f"array{i}")) for i in range(n)]
    straggle_rng = [
        Rng(hash_seed(seed ^ STRAGGLE_SALT, f"array{i}")) for i in range(n)
    ]

    queue = EventQueue()
    up = [True] * n
    down_since = [0.0] * n
    if chaos_has_failures(chaos):
        for i in range(n):
            queue.push(exp_interval(fail_rng[i], 1.0 / mtbf), ("down", i))

    stats = {
        "epochs": 0,
        "retries": 0,
        "failures": 0,
        "recoveries": 0,
        "downtime": 0.0,
        "straggled": 0,
    }
    lanes = [[0.0, 0] for _ in range(n)]
    finish_times = [0.0] * n_req
    done = [False] * n_req
    pending = list(range(n_req))
    link_bytes = 0.0
    makespan = 0.0
    t = 0.0

    while pending:
        force_all_up = stats["epochs"] >= MAX_EPOCHS
        if force_all_up:
            epoch_end = INF
        else:
            pt = queue.peek_time()
            epoch_end = pt if pt is not None else INF
        if force_all_up:
            live = list(range(n))
        else:
            live = [i for i in range(n) if up[i]]

        if not live:
            et, ev = queue.pop()
            apply_transition(ev, et, chaos, up, down_since, fail_rng, queue, stats)
            t = et
            continue

        speeds = [fleet[i][0] for i in live]
        if not force_all_up and chaos_has_stragglers(chaos):
            for k, i in enumerate(live):
                if straggle_rng[i].gen_f64() < straggle_p:
                    speeds[k] /= straggle_factor
                    stats["straggled"] += 1
        stats["epochs"] += 1

        if strategy == "data":
            placements = epoch_data_parallel(
                durations, arrivals, pending, live, speeds, t, epoch_end
            )
        elif strategy == "pipeline":
            placements = epoch_layer_pipeline(
                durations, out_bytes, arrivals, pending, live, speeds, t, epoch_end
            )
        else:
            placements = epoch_tensor_shard(
                durations,
                tiles,
                out_bytes,
                arrivals,
                pending,
                live,
                speeds,
                fleet,
                t,
                epoch_end,
            )

        for p in placements:
            if p["finish"] <= epoch_end:
                done[p["req"]] = True
                finish_times[p["req"]] = p["finish"]
                makespan = max(makespan, p["finish"])
                link_bytes += p["bytes"]
                for array, busy, jobs in p["lanes"]:
                    lanes[array][0] += busy
                    lanes[array][1] += jobs
            elif p["start"] < epoch_end:
                stats["retries"] += 1
        pending = [r for r in pending if not done[r]]
        if not pending:
            break

        if math.isfinite(epoch_end):
            et, ev = queue.pop()
            apply_transition(ev, et, chaos, up, down_since, fail_rng, queue, stats)
            t = et
        else:
            raise AssertionError("unbounded epoch left requests pending")

    return {
        "lanes": lanes,
        "finish_times": finish_times,
        "makespan": makespan,
        "link_bytes": link_bytes,
        "mandatory_transfer": mandatory,
        "lower_bound": lower_bound,
        "stats": stats,
    }


def random_arrivals(rng, r):
    if rng.random() < 0.4:
        return [0.0] * r
    t = 0.0
    out = [0.0]
    for _ in range(r - 1):
        t += rng.uniform(0, 2e-2)
        out.append(t)
    return out


def replay_rust_unit_tests():
    """Replay the exact inputs of the unit tests in
    rust/src/cluster/event.rs and the weighted-stage tests in
    rust/src/cluster/shard.rs through the transcription, asserting the
    same things the Rust tests assert — the assertions with a stochastic
    ingredient are pre-verified here rather than hoped-for in CI."""
    d = [0.4, 0.2, 0.3, 0.1]
    tiles = [8, 8, 4, 4]
    bts = [1e6, 5e5, 2.5e5, 1e5]
    chain = sum(d)

    # apportion_is_exact_deterministic_and_weighted
    assert apportion(10, [2.0, 1.0, 1.0]) == [5, 3, 2]
    assert apportion(3, [1.0, 1.0]) == [2, 1]
    assert apportion(0, [1.0, 2.0]) == [0, 0]
    assert apportion(7, [1.0]) == [7]
    s = apportion(13, [3.0, 2.0, 1.0])
    assert s[0] >= s[1] >= s[2], s

    # weighted_stages_with_unit_speeds_match_homogeneous
    dd = [3.0, 1.0, 1.0, 1.0, 2.0, 2.0]
    for n in range(1, 7):
        assert balanced_stages_weighted(dd, [1.0] * n) == balanced_stages(dd, n), n
    assert balanced_stages_weighted([], [1.0, 1.0]) == [0]
    assert balanced_stages_weighted(dd, [1.0]) == [6]

    # weighted_stages_give_fast_arrays_more_wall_balanced_work
    du = [1.0] * 6
    ends = balanced_stages_weighted(du, [2.0, 1.0])
    assert ends[-1] == 6 and len(ends) == 2 and ends[0] == 4, ends
    assert balanced_stages_weighted(du, [1.0, 2.0])[0] == 2

    def wall(ends, speeds, durs):
        lo, worst = 0, 0.0
        for st, e in enumerate(ends):
            work = sum(durs[lo:e])
            worst = max(worst, work / speeds[min(st, len(speeds) - 1)])
            lo = e
        return worst

    naive = balanced_stages(du, 2)
    assert wall(ends, [2.0, 1.0], du) <= wall(naive, [2.0, 1.0], du) + 1e-12

    # chaos_off_uniform_completes_in_one_epoch
    arrivals = [0.0, 0.1, 0.2, 0.5]
    fleet = [(1.0, 1.0)] * 3
    for strat in STRATS:
        out = run_chaos(strat, d, tiles, bts, arrivals, fleet, CHAOS_OFF, 7)
        assert out["stats"]["epochs"] == 1, strat
        assert out["stats"]["retries"] == 0
        assert out["stats"]["failures"] == 0
        assert len(out["finish_times"]) == 4
        for f, a in zip(out["finish_times"], arrivals):
            assert f >= a + chain / 1.0 - 1e-12 or strat != "data", (strat, f, a)
            assert f > a, strat
        assert out["makespan"] >= out["lower_bound"] - 1e-12, strat

    # heterogeneous_fleet_beats_its_slowest_and_holds_the_bound
    zero8 = [0.0] * 8
    fast = [(2.0, 1.0), (2.0, 1.0), (1.0, 1.0), (1.0, 1.0)]
    slow = [(1.0, 1.0)] * 4
    for strat in STRATS:
        f = run_chaos(strat, d, tiles, bts, zero8, fast, CHAOS_OFF, 7)
        sl = run_chaos(strat, d, tiles, bts, zero8, slow, CHAOS_OFF, 7)
        assert f["makespan"] <= sl["makespan"] + 1e-12, (
            strat,
            f["makespan"],
            sl["makespan"],
        )
        assert f["makespan"] >= f["lower_bound"] - 1e-12
        assert sl["makespan"] >= sl["lower_bound"] - 1e-12

    # failures_retry_and_still_complete_exactly_once
    arr16 = [i * 0.1 for i in range(16)]
    uni4 = [(1.0, 1.0)] * 4
    retry_chaos = (0.5, 0.2, 0.0, 1.0)
    for strat in STRATS:
        out = run_chaos(strat, d, tiles, bts, arr16, uni4, retry_chaos, 11)
        assert out["stats"]["failures"] > 0, strat
        assert len(out["finish_times"]) == 16
        for f, a in zip(out["finish_times"], arr16):
            assert f > a, (strat, f, a)
        assert out["makespan"] >= out["lower_bound"] - 1e-12, strat
        calm = run_chaos(strat, d, tiles, bts, arr16, uni4, CHAOS_OFF, 11)
        assert calm["makespan"] <= out["makespan"] + 1e-12, (
            strat,
            out["makespan"],
            calm["makespan"],
        )

    # chaos_runs_are_deterministic_per_seed
    arr12 = [i * 0.05 for i in range(12)]
    het4 = [(1.0, 1.0), (1.0, 1.0), (0.5, 1.0), (0.5, 1.0)]
    det_chaos = (0.8, 0.3, 0.3, 3.0)
    for strat in STRATS:
        a = run_chaos(strat, d, tiles, bts, arr12, het4, det_chaos, 42)
        b = run_chaos(strat, d, tiles, bts, arr12, het4, det_chaos, 42)
        assert a == b, strat
        c = run_chaos(strat, d, tiles, bts, arr12, het4, det_chaos, 43)
        assert a["stats"] != c["stats"], (strat, a["stats"])

    # stragglers_slow_the_run_without_failures
    arr20 = [i * 0.05 for i in range(20)]
    st_chaos = (0.4, 0.1, 0.5, 8.0)
    just_fail = (0.4, 0.1, 0.0, 1.0)
    with_st = run_chaos("data", d, tiles, bts, arr20, uni4, st_chaos, 5)
    assert with_st["stats"]["straggled"] > 0
    assert with_st["makespan"] >= with_st["lower_bound"] - 1e-12
    without = run_chaos("data", d, tiles, bts, arr20, uni4, just_fail, 5)
    assert without["stats"]["straggled"] == 0
    assert without["stats"]["failures"] == with_st["stats"]["failures"], (
        without["stats"],
        with_st["stats"],
    )

    # dark_fleet_waits_for_recovery
    dark = run_chaos(
        "data", d, tiles, bts, [0.0] * 4, [(1.0, 1.0)], (0.05, 1.0, 0.0, 1.0), 3
    )
    assert len(dark["finish_times"]) == 4
    assert dark["stats"]["failures"] > 0
    assert dark["stats"]["downtime"] > 0.0
    assert dark["makespan"] >= dark["lower_bound"] - 1e-12
    assert all(f > 0.0 for f in dark["finish_times"])

    # degenerate inputs the engine must survive
    empty = run_chaos("data", d, tiles, bts, [], uni4, CHAOS_OFF, 1)
    assert empty["finish_times"] == [] and empty["makespan"] == 0.0
    assert empty["stats"]["epochs"] == 0


def main():
    rng = random.Random(20260727)
    cases = 0

    replay_rust_unit_tests()

    # --- arrays=1 degeneracy + lower bounds, all strategies ---
    for trial in range(6000):
        length = rng.randint(1, 12)
        durations = [rng.uniform(1e-6, 1e-2) for _ in range(length)]
        tiles = [rng.randint(1, 64) for _ in range(length)]
        out_bytes = [rng.uniform(1e3, 1e7) for _ in range(length)]
        arrivals = random_arrivals(rng, rng.randint(1, 16))
        batch = rng.randint(1, 6)
        overlap = rng.choice([0.0, 0.3, 0.6, 0.95])
        arrays = rng.randint(1, 10)
        ctx = (trial, length, batch, overlap, arrays, len(arrivals))

        ref_jobs, ref_ft, ref_m, ref_busy = chain_build(
            durations, arrivals, batch, overlap
        )
        runs = {
            "data": data_parallel(durations, arrivals, batch, overlap, arrays),
            "pipeline": layer_pipeline(
                durations, out_bytes, arrivals, batch, overlap, arrays
            ),
            "tensor": tensor_shard(
                durations, tiles, out_bytes, arrivals, batch, overlap, arrays
            ),
        }
        for tag, (lanes, ft, m, mandatory, lower) in runs.items():
            eps = abs(m) * 1e-12 + 1e-15
            assert m >= lower - eps, (ctx, tag, m, lower)
            assert len(lanes) == arrays, (ctx, tag)
            for busy, _jobs in lanes:
                assert busy <= m + 1e-12, (ctx, tag, busy, m)
            assert len(ft) == len(arrivals), (ctx, tag)
        # exact single-array degeneracy (same float ops, same values)
        one = {
            "data": data_parallel(durations, arrivals, batch, overlap, 1),
            "pipeline": layer_pipeline(
                durations, out_bytes, arrivals, batch, overlap, 1
            ),
            "tensor": tensor_shard(
                durations, tiles, out_bytes, arrivals, batch, overlap, 1
            ),
        }
        for tag, (lanes, ft, m, mandatory, _lower) in one.items():
            assert m == ref_m, (ctx, tag, m, ref_m)
            assert ft == ref_ft, (ctx, tag)
            assert lanes[0][0] == ref_busy, (ctx, tag)
            assert lanes[0][1] == len(ref_jobs), (ctx, tag)
            assert mandatory == 0.0, (ctx, tag)
        cases += 1

    # --- DataParallel closed-loop monotonicity in the array count ---
    for trial in range(3000):
        length = rng.randint(1, 10)
        durations = [rng.uniform(1e-6, 1e-2) for _ in range(length)]
        requests = rng.randint(1, 24)
        arrivals = [0.0] * requests
        batch = rng.randint(1, 6)
        overlap = rng.choice([0.0, 0.4, 0.8, 0.95])
        prev = float("inf")
        for arrays in range(1, requests + 3):
            _, _, m, _, lower = data_parallel(
                durations, arrivals, batch, overlap, arrays
            )
            assert m <= prev + 1e-12, (trial, arrays, batch, overlap, m, prev)
            assert m >= lower - abs(m) * 1e-12 - 1e-15, (trial, arrays, m, lower)
            prev = m
        cases += 1

    # --- pipeline stages respect per-request chain + transfer floors ---
    for trial in range(2000):
        length = rng.randint(2, 12)
        durations = [rng.uniform(1e-5, 1e-2) for _ in range(length)]
        out_bytes = [rng.uniform(1e4, 1e8) for _ in range(length)]
        arrivals = random_arrivals(rng, rng.randint(1, 12))
        arrays = rng.randint(2, 6)
        _, ft, m, mandatory, lower = layer_pipeline(
            durations, out_bytes, arrivals, 1, 0.0, arrays
        )
        chain = critical_path_chain(durations)
        for f, a in zip(ft, arrivals):
            assert f - a >= chain + mandatory - 1e-12, (
                trial,
                arrays,
                f,
                a,
                chain,
                mandatory,
            )
        assert m >= max(ft) - 1e-15, (trial, m, max(ft))
        cases += 1

    # --- apportion: exact, deterministic, quota-faithful ---
    for trial in range(2000):
        k = rng.randint(1, 8)
        total = rng.randint(0, 500)
        if rng.random() < 0.05:
            weights = [0.0] * k
        else:
            weights = [rng.choice([0.25, 0.5, 1.0, 2.0, 4.0]) for _ in range(k)]
        shares = apportion(total, weights)
        assert len(shares) == k
        assert sum(shares) == total, (trial, total, weights, shares)
        assert shares == apportion(total, weights), "must be deterministic"
        w_sum = sum(weights)
        if w_sum > 0.0:
            for w, s in zip(weights, shares):
                q = total * w / w_sum
                assert abs(s - q) < 1.0 + 1e-9, (trial, total, weights, shares)
            # heavier weight never gets fewer tiles (ties allowed)
            pairs = sorted(zip(weights, shares), key=lambda p: -p[0])
            for (wa, sa), (wb, sb) in zip(pairs, pairs[1:]):
                if wa > wb:
                    assert sa >= sb, (trial, total, weights, shares)
        cases += 1

    # --- weighted stage cutter: unit-speed equality + structure ---
    for trial in range(2000):
        length = rng.randint(0, 12)
        durations = [rng.uniform(1e-5, 1e-2) for _ in range(length)]
        n = rng.randint(1, 8)
        assert balanced_stages_weighted(durations, [1.0] * n) == balanced_stages(
            durations, n
        ), (trial, durations, n)
        # nonpositive / nonfinite speeds clamp to the unit-speed cut
        degenerate = rng.choice([-1.0, 0.0, float("nan"), INF])
        assert balanced_stages_weighted(durations, [degenerate] * n) == (
            balanced_stages_weighted(durations, [1.0] * n)
        ), (trial, degenerate)
        speeds = [rng.choice([0.25, 0.5, 1.0, 2.0, 4.0]) for _ in range(n)]
        ends = balanced_stages_weighted(durations, speeds)
        if length == 0:
            assert ends == [0]
        else:
            assert ends[-1] == length, (trial, ends)
            assert len(ends) <= n
            assert all(a < b for a, b in zip(ends, ends[1:])), (trial, ends)
        cases += 1

    # --- chaos engine: exactly-once, bounds, determinism, decorrelation ---
    saw_retries = saw_failures = saw_straggles = saw_zero_tiles = 0
    for trial in range(3000):
        length = rng.randint(1, 8)
        durations = [rng.uniform(1e-3, 5e-2) for _ in range(length)]
        tiles = [
            0 if rng.random() < 0.1 else rng.randint(1, 64) for _ in range(length)
        ]
        out_bytes = [rng.uniform(1e3, 1e7) for _ in range(length)]
        chain = sum(durations)
        requests = rng.randint(1, 10)
        if rng.random() < 0.4:
            arrivals = [0.0] * requests
        else:
            arrivals, acc = [], 0.0
            for _ in range(requests):
                arrivals.append(acc)
                acc += rng.uniform(0.0, chain * 0.5)
        fleet = [
            (rng.choice([0.25, 0.5, 1.0, 2.0, 4.0]), rng.choice([0.5, 1.0, 2.0]))
            for _ in range(rng.randint(1, 6))
        ]
        min_speed = min(sp for sp, _sz in fleet)
        if rng.random() < 0.5:
            p, factor = 0.0, 1.0
        else:
            p, factor = rng.uniform(0.05, 0.9), rng.uniform(1.5, 4.0)
        service_worst = chain / (min_speed / factor)
        if rng.random() < 0.35:
            mtbf, mttr = INF, 0.0
        else:
            # moderate chaos: epochs long enough that requests progress
            # (pathological flapping is covered by the stress loop + the
            # MAX_EPOCHS forced-completion backstop)
            mtbf = rng.uniform(2.0, 16.0) * service_worst
            mttr = 0.0 if rng.random() < 0.2 else rng.uniform(0.0, service_worst)
        chaos = (mtbf, mttr, p, factor)
        seed = rng.getrandbits(63)
        ctx = (trial, length, requests, fleet, chaos, seed)

        for strat in STRATS:
            out = run_chaos(
                strat, durations, tiles, out_bytes, arrivals, fleet, chaos, seed
            )
            st = out["stats"]
            ft = out["finish_times"]
            # exactly-once: one finite finish per accepted request,
            # strictly after its arrival, no matter what failed
            assert len(ft) == requests, ctx
            for f, a in zip(ft, arrivals):
                assert math.isfinite(f) and f > a, (ctx, strat, f, a)
            assert out["makespan"] == max(ft), (ctx, strat)
            eps = out["makespan"] * 1e-12 + 1e-12
            assert out["makespan"] >= out["lower_bound"] - eps, (
                ctx,
                strat,
                out["makespan"],
                out["lower_bound"],
            )
            assert len(out["lanes"]) == len(fleet), (ctx, strat)
            for busy, jobs in out["lanes"]:
                assert busy >= 0.0 and jobs >= 0, (ctx, strat)
                assert busy <= out["makespan"] + eps, (ctx, strat, busy)
            assert out["link_bytes"] >= 0.0
            if strat == "data":
                assert out["link_bytes"] == 0.0, (ctx, strat)
            assert st["epochs"] <= MAX_EPOCHS + 1, (ctx, strat)
            assert st["recoveries"] <= st["failures"], (ctx, strat)
            if chaos == CHAOS_OFF:
                assert st["epochs"] == 1, (ctx, strat)
                assert st["retries"] == 0 and st["failures"] == 0, (ctx, strat)
                assert st["downtime"] == 0.0 and st["straggled"] == 0, (ctx, strat)
            saw_retries += st["retries"]
            saw_failures += st["failures"]
            saw_straggles += st["straggled"]
            if trial % 3 == 0:
                again = run_chaos(
                    strat, durations, tiles, out_bytes, arrivals, fleet, chaos, seed
                )
                assert again == out, (ctx, strat, "seed determinism broke")
            if trial % 5 == 0 and chaos_has_stragglers(chaos):
                # decorrelated streams: dropping stragglers never
                # touches the straggle counter of a straggle-free run
                no_st = run_chaos(
                    strat,
                    durations,
                    tiles,
                    out_bytes,
                    arrivals,
                    fleet,
                    (mtbf, mttr, 0.0, 1.0),
                    seed,
                )
                assert no_st["stats"]["straggled"] == 0, (ctx, strat)
        saw_zero_tiles += sum(1 for tl in tiles if tl == 0)
        cases += 1
    assert saw_failures > 0, "chaos corpus never exercised a failure"
    assert saw_retries > 0, "chaos corpus never exercised a retry"
    assert saw_straggles > 0, "chaos corpus never exercised a straggler"
    assert saw_zero_tiles > 0, "chaos corpus never exercised tiles == 0"

    # --- stress: harsh failure rates around the per-request service ---
    stress_retries = 0
    for trial in range(300):
        length = rng.randint(1, 5)
        durations = [rng.uniform(1e-2, 5e-2) for _ in range(length)]
        tiles = [rng.randint(1, 32) for _ in range(length)]
        out_bytes = [rng.uniform(1e3, 1e6) for _ in range(length)]
        chain = sum(durations)
        requests = rng.randint(1, 6)
        arrivals = [0.0] * requests
        fleet = [(1.0, 1.0)] * rng.randint(1, 4)
        mtbf = rng.uniform(0.6, 2.0) * chain
        mttr = rng.uniform(0.0, chain)
        chaos = (mtbf, mttr, 0.0, 1.0)
        seed = rng.getrandbits(63)
        for strat in STRATS:
            out = run_chaos(
                strat, durations, tiles, out_bytes, arrivals, fleet, chaos, seed
            )
            assert len(out["finish_times"]) == requests
            assert all(math.isfinite(f) and f > 0.0 for f in out["finish_times"])
            eps = out["makespan"] * 1e-12 + 1e-12
            assert out["makespan"] >= out["lower_bound"] - eps, (trial, strat)
            stress_retries += out["stats"]["retries"]
        cases += 1
    assert stress_retries > 0, "stress corpus never killed a request mid-flight"

    print(
        f"all {cases} cluster fuzz cases satisfy the scale-out and "
        "chaos-engine invariants"
    )


if __name__ == "__main__":
    main()
