#!/usr/bin/env python3
"""Line-for-line Python transcription of rust/src/cluster/schedule.rs
(`data_parallel`, the chain case of `layer_pipeline`, `tensor_shard`)
and rust/src/cluster/shard.rs (`balanced_stages`, the link model),
fuzzed against the invariants `rust/tests/cluster_equivalence.rs`
enforces in CI:

  * every strategy at arrays = 1 is EXACTLY the single-array pipeline
    (same makespan / finish times / busy — same float ops, same bits);
  * DataParallel makespan is monotone non-increasing in the array count
    under closed-loop load (every request queued at t = 0);
  * per-strategy makespan >= critical path + mandatory transfer time
    (TensorShard's gather rides inside its effective durations);
  * per-replica/stage busy never exceeds the cluster makespan; every
    request's completion respects its own chain + transfers.

The single-array scheduler transcription is imported from
scripts/fuzz_serve_pipeline.py (kept in sync with serve/pipeline.rs).
Run `python3 scripts/fuzz_cluster.py`; exits nonzero with the offending
configuration on any violation. Keep this file in sync with
rust/src/cluster/ when touching scheduler semantics (see
.claude/skills/verify/SKILL.md).
"""

import os
import random
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from fuzz_serve_pipeline import build, critical_path_chain, topo_chain  # noqa: E402

LINK_BYTES_PER_S = 25.0e9


def link_seconds(b):
    return b / LINK_BYTES_PER_S


def chain_build(durations, arrivals, batch, overlap):
    n = len(durations)
    topo, deps = topo_chain(n)
    return build(n, deps, topo, durations, arrivals, batch, overlap, [n - 1])


def balanced_stages(durations, n):
    """Transcription of shard::balanced_stages."""
    ln = len(durations)
    stages = min(max(n, 1), max(ln, 1))
    if ln == 0:
        return [0]
    total = 0.0
    for d in durations:
        total = total + d
    longest = 0.0
    for d in durations:
        longest = max(longest, d)

    def cut(cap):
        ends = []
        acc = 0.0
        for i, d in enumerate(durations):
            if acc > 0.0 and acc + d > cap:
                ends.append(i)
                acc = 0.0
            acc += d
        ends.append(ln)
        return ends

    lo, hi = longest, total
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if len(cut(mid)) <= stages:
            hi = mid
        else:
            lo = mid
    ends = cut(hi)
    while len(ends) > stages:
        last = ends.pop()
        ends[-1] = last
    return ends


def data_parallel(durations, arrivals, batch, overlap, arrays):
    """Transcription of schedule::data_parallel (chain DAG)."""
    arrays = max(arrays, 1)
    member = [[] for _ in range(arrays)]
    for i in range(len(arrivals)):
        member[i % arrays].append(i)
    lanes = []
    finish_times = [0.0] * len(arrivals)
    makespan = 0.0
    for requests in member:
        sub = [arrivals[i] for i in requests]
        jobs, ft, m, busy = chain_build(durations, sub, batch, overlap)
        for slot, i in enumerate(requests):
            finish_times[i] = ft[slot]
        makespan = max(makespan, m)
        lanes.append((busy, len(jobs)))
    lower = max((a + critical_path_chain(durations) for a in arrivals), default=0.0)
    return lanes, finish_times, makespan, 0.0, lower


def layer_pipeline(durations, out_bytes, arrivals, batch, overlap, arrays):
    """Transcription of schedule::layer_pipeline for a chain DAG (the
    zoo topology): each stage is a contiguous sub-chain, and the only
    edge into stage s is from the last node of stage s-1."""
    arrays = max(arrays, 1)
    ends = balanced_stages(durations, arrays)
    if len(ends) == 1:
        jobs, ft, m, busy = chain_build(durations, arrivals, batch, overlap)
        lanes = [(0.0, 0)] * arrays
        lanes[0] = (busy, len(jobs))
        lower = max(
            (a + critical_path_chain(durations) for a in arrivals), default=0.0
        )
        return lanes, ft, m, 0.0, lower
    lanes = [(0.0, 0)] * arrays
    makespan = 0.0
    mandatory = 0.0
    stage_arrivals = list(arrivals)
    finish_times = list(arrivals)
    lo = 0
    for s, hi in enumerate(ends):
        if s > 0:
            moved = out_bytes[lo - 1]  # chain: one boundary producer
            t = link_seconds(moved)
            mandatory += t
            stage_arrivals = [f + t for f in finish_times]
        # build() requires a sorted arrival timeline; downstream stages
        # must inherit sortedness from the finish-time ordering (the
        # Rust side debug_asserts the same property)
        assert all(
            a <= b for a, b in zip(stage_arrivals, stage_arrivals[1:])
        ), (s, stage_arrivals)
        sub_durs = durations[lo:hi]
        jobs, ft, m, busy = chain_build(sub_durs, stage_arrivals, batch, overlap)
        lanes[s] = (busy, len(jobs))
        makespan = max(makespan, m)
        finish_times = ft
        lo = hi
    lower = max(
        (a + critical_path_chain(durations) + mandatory for a in arrivals),
        default=0.0,
    )
    return lanes, finish_times, makespan, mandatory, lower


def tensor_shard(durations, tiles, out_bytes, arrivals, batch, overlap, arrays):
    """Transcription of schedule::tensor_shard (chain DAG)."""
    arrays = max(arrays, 1)
    n = float(arrays)
    mandatory = 0.0
    d_sched = []
    for d, t, b in zip(durations, tiles, out_bytes):
        share = 1.0 if t == 0 else (-(-t // arrays)) / t
        if arrays > 1:
            gather = link_seconds(b) * (n - 1.0) / n
        else:
            gather = 0.0
        mandatory += gather
        d_sched.append(d * share + gather)
    jobs, ft, m, busy = chain_build(d_sched, arrivals, batch, overlap)
    lanes = [(busy, len(jobs))] * arrays
    lower = max((a + critical_path_chain(d_sched) for a in arrivals), default=0.0)
    return lanes, ft, m, mandatory, lower


def random_arrivals(rng, r):
    if rng.random() < 0.4:
        return [0.0] * r
    t = 0.0
    out = [0.0]
    for _ in range(r - 1):
        t += rng.uniform(0, 2e-2)
        out.append(t)
    return out


def main():
    rng = random.Random(20260727)
    cases = 0

    # --- arrays=1 degeneracy + lower bounds, all strategies ---
    for trial in range(6000):
        length = rng.randint(1, 12)
        durations = [rng.uniform(1e-6, 1e-2) for _ in range(length)]
        tiles = [rng.randint(1, 64) for _ in range(length)]
        out_bytes = [rng.uniform(1e3, 1e7) for _ in range(length)]
        arrivals = random_arrivals(rng, rng.randint(1, 16))
        batch = rng.randint(1, 6)
        overlap = rng.choice([0.0, 0.3, 0.6, 0.95])
        arrays = rng.randint(1, 10)
        ctx = (trial, length, batch, overlap, arrays, len(arrivals))

        ref_jobs, ref_ft, ref_m, ref_busy = chain_build(
            durations, arrivals, batch, overlap
        )
        runs = {
            "data": data_parallel(durations, arrivals, batch, overlap, arrays),
            "pipeline": layer_pipeline(
                durations, out_bytes, arrivals, batch, overlap, arrays
            ),
            "tensor": tensor_shard(
                durations, tiles, out_bytes, arrivals, batch, overlap, arrays
            ),
        }
        for tag, (lanes, ft, m, mandatory, lower) in runs.items():
            eps = abs(m) * 1e-12 + 1e-15
            assert m >= lower - eps, (ctx, tag, m, lower)
            assert len(lanes) == arrays, (ctx, tag)
            for busy, _jobs in lanes:
                assert busy <= m + 1e-12, (ctx, tag, busy, m)
            assert len(ft) == len(arrivals), (ctx, tag)
        # exact single-array degeneracy (same float ops, same values)
        one = {
            "data": data_parallel(durations, arrivals, batch, overlap, 1),
            "pipeline": layer_pipeline(
                durations, out_bytes, arrivals, batch, overlap, 1
            ),
            "tensor": tensor_shard(
                durations, tiles, out_bytes, arrivals, batch, overlap, 1
            ),
        }
        for tag, (lanes, ft, m, mandatory, _lower) in one.items():
            assert m == ref_m, (ctx, tag, m, ref_m)
            assert ft == ref_ft, (ctx, tag)
            assert lanes[0][0] == ref_busy, (ctx, tag)
            assert lanes[0][1] == len(ref_jobs), (ctx, tag)
            assert mandatory == 0.0, (ctx, tag)
        cases += 1

    # --- DataParallel closed-loop monotonicity in the array count ---
    for trial in range(3000):
        length = rng.randint(1, 10)
        durations = [rng.uniform(1e-6, 1e-2) for _ in range(length)]
        requests = rng.randint(1, 24)
        arrivals = [0.0] * requests
        batch = rng.randint(1, 6)
        overlap = rng.choice([0.0, 0.4, 0.8, 0.95])
        prev = float("inf")
        for arrays in range(1, requests + 3):
            _, _, m, _, lower = data_parallel(
                durations, arrivals, batch, overlap, arrays
            )
            assert m <= prev + 1e-12, (trial, arrays, batch, overlap, m, prev)
            assert m >= lower - abs(m) * 1e-12 - 1e-15, (trial, arrays, m, lower)
            prev = m
        cases += 1

    # --- pipeline stages respect per-request chain + transfer floors ---
    for trial in range(2000):
        length = rng.randint(2, 12)
        durations = [rng.uniform(1e-5, 1e-2) for _ in range(length)]
        out_bytes = [rng.uniform(1e4, 1e8) for _ in range(length)]
        arrivals = random_arrivals(rng, rng.randint(1, 12))
        arrays = rng.randint(2, 6)
        _, ft, m, mandatory, lower = layer_pipeline(
            durations, out_bytes, arrivals, 1, 0.0, arrays
        )
        chain = critical_path_chain(durations)
        for f, a in zip(ft, arrivals):
            assert f - a >= chain + mandatory - 1e-12, (
                trial,
                arrays,
                f,
                a,
                chain,
                mandatory,
            )
        assert m >= max(ft) - 1e-15, (trial, m, max(ft))
        cases += 1

    print(f"all {cases} cluster fuzz cases satisfy the scale-out invariants")


if __name__ == "__main__":
    main()
