#!/usr/bin/env python3
"""Line-for-line Python transcription of rust/src/serve/pipeline.rs
(`PipelineSchedule::build`, `serial_makespan`) and the chain case of
rust/src/serve/dag.rs (`critical_path`), fuzzed against the schedule
invariants `rust/tests/serve_equivalence.rs` enforces in CI:

  * critical path  max_i(arrival_i + chain) <= makespan;
  * makespan <= serial reference under the same batch-forming policy;
  * overlap = 0 equals that reference exactly (single resource:
    batching alone only reorders, the gain comes from overlap);
  * batch=1 / overlap=0 / one request == bit-exact serial wall sum;
  * finishes strictly increase; busy union bounded; latency floor is
    the dependency chain; makespan monotone non-increasing in overlap;
  * general-DAG dependency respect (diamond topology).

Run `python3 scripts/fuzz_serve_pipeline.py`; exits nonzero with the
offending configuration on any violation. Keep this file in sync with
rust/src/serve/pipeline.rs when touching scheduler semantics (see
.claude/skills/verify/SKILL.md).

Also transcribed here: the per-layer cycle formulas of the analytic
comparator backends (rust/src/backend/analytic.rs lifting
rust/src/baseline/{naive,scnn,sparten,gating}.rs). The analytic-backend
oracle case feeds backend-generated per-layer walls through the same
schedule transcription and checks the trait-dispatch contract
rust/tests/backend_equivalence.rs enforces in CI: a batch=1 / overlap=0
single-request makespan is bit-exactly the left-fold of the analytic
per-layer walls, and the golden closed forms (76 naive / 310 scnn /
266 sparten / 500 gating cycles) survive the transcription.

And the fast-path oracle (`fastpath_oracle`): a transcription of
rust/src/serve/fastpath.rs — wave-template construction
(`build_template`/`steady_info`), the streaming replay, and the
steady-state gate of `evaluate` — fuzzed against the `build`
transcription above. The replay layers must match *bit for bit*
(compared through `struct.pack`, mirroring `to_bits()` in
rust/tests/serve_fastpath.rs); the steady-state extrapolation must
engage on saturated closed-loop backlogs, stay within the documented
n·ε relative bound, and stay disengaged (hence bit-exact) when
arrivals outrun the array.

And the dynamic-density oracle (`dynamic_density_oracle`): a
transcription of rust/src/serve/density.rs (salted per-request xoshiro
streams, 16-level quantization, `realized_rows` and the lazily
evaluated `RowStream`) plus the dynamic scheduler family —
`PipelineSchedule::build_windows_dynamic`, the shared `drive_dynamic`
loop behind `fastpath::evaluate_windows_dynamic` (materialized rows)
and `fastpath::evaluate_windows_streamed` (window-by-window streaming
with the template-alphabet cache), and the per-template ensemble
steady-state layer. Fuzzed for bit-equality between the exact engine,
the rows-based fast path, and the streamed fast path (with the
alphabet cache both on and off — a too-coarse cache key would diverge
on some sampled case) across thousands of sampled-density cases
(every model family, chain and skip DAGs, batch and SLO window
partitions); for the ensemble steady layer engaging on saturated
deep backlogs within the documented <1e-9 relative bound while
spread arrivals and `steady=False` stay bit-exact; and for the
degenerate anchor: rows that all equal the static duration vector
must reproduce the static builder bit for bit.

And the traffic-engine oracle (`traffic_oracle`): a transcription of
rust/src/util/rng.rs (SplitMix64 -> xoshiro256++) and the arrival
generators + SLO window closure of rust/src/serve/traffic.rs /
rust/src/serve/workload.rs. The uniform baseline must reproduce the
seed-7 bit goldens `open_loop_seed7_sequence_is_bit_stable` locks
(pure arithmetic, toolchain-independent); the stochastic generators
are checked for seed determinism (byte-compared through struct.pack),
ordering/finiteness, empirical rate, and MMPP over-dispersion; and
the `windows` transcription is fuzzed bit-for-bit against an
independently formulated online admission-queue oracle across every
generator family, pathological timelines, and slo in {0, ..., inf}.
"""

import math
import random
import struct

MAX_OVERLAP = 0.95
CLK = 500.0 * 1e6  # MAC_FREQ_MHZ as f64 * 1e6


def topo_chain(n):
    return list(range(n)), [([] if i == 0 else [i - 1]) for i in range(n)]


def build(n_nodes, deps, topo, durations, arrivals, batch, overlap, sinks):
    """Transcription of PipelineSchedule::build."""
    overlap = min(max(overlap, 0.0), MAX_OVERLAP)
    batch = max(batch, 1)
    n_img = len(arrivals)
    finish = [0.0] * (n_img * n_nodes)
    jobs = []
    finish_times = [0.0] * n_img
    array_free = 0.0
    prev_dur = 0.0
    any_prev = False
    busy = 0.0
    makespan = 0.0
    window = 0
    while window * batch < n_img:
        lo = window * batch
        hi = min(lo + batch, n_img)
        window_ready = 0.0
        for a in arrivals[lo:hi]:
            window_ready = max(window_ready, a)
        for node in topo:
            d = durations[node]
            for img in range(lo, hi):
                ready = window_ready
                for p in deps[node]:
                    ready = max(ready, finish[img * n_nodes + p])
                if any_prev:
                    start = max(ready, array_free - overlap * min(prev_dur, d))
                else:
                    start = ready
                end = start + d
                busy += end - (max(start, array_free) if any_prev else start)
                finish[img * n_nodes + node] = end
                jobs.append((img, node, start, end))
                array_free = end
                prev_dur = d
                any_prev = True
                makespan = max(makespan, end)
        for img in range(lo, hi):
            done = window_ready
            for s in sinks:
                done = max(done, finish[img * n_nodes + s])
            finish_times[img] = done
        window += 1
    return jobs, finish_times, makespan, busy


def critical_path_chain(durations):
    """dag.critical_path on a chain (same left-fold association)."""
    best = 0.0
    longest = 0.0
    for d in durations:
        longest = longest + d
        best = max(best, longest)
    return best


def serial_makespan(durations, arrivals, batch):
    """Transcription of pipeline::serial_makespan (total work per
    image — equals the critical path on chains, exceeds it on DAGs)."""
    work = 0.0
    for d in durations:
        work = work + d
    batch = max(batch, 1)
    n = len(arrivals)
    t = 0.0
    w = 0
    while w * batch < n:
        lo = w * batch
        hi = min(lo + batch, n)
        ready = 0.0
        for a in arrivals[lo:hi]:
            ready = max(ready, a)
        t = max(t, ready) + (hi - lo) * work
        w += 1
    return t


# --- fast-path transcription (rust/src/serve/fastpath.rs) -------------

STEADY_MIN_WINDOWS = 64


def _bits(x):
    """f64 bit pattern, the Python spelling of `to_bits()`."""
    return struct.pack("<d", x)


def _steady_info(n_nodes, deps, topo, width, dur, cut, topo_pos, sinks,
                 entry_any_prev):
    """Transcription of fastpath::steady_info."""
    if not entry_any_prev or n_nodes == 0 or width == 0 or not sinks:
        return None
    b = []
    b_prev = 0.0
    busy_delta = 0.0
    theta = 0.0
    bmag = 0.0
    job = 0
    for node in topo:
        for s in range(width):
            lower = b_prev - cut[job]
            for p in deps[node]:
                lower = max(lower, b[topo_pos[p] * width + s])
            theta = max(theta, -lower)
            end = lower + dur[job]
            busy_delta += end - max(lower, b_prev)
            if not math.isfinite(end):
                return None
            bmag = max(bmag, abs(end), abs(cut[job]))
            b.append(end)
            b_prev = end
            job += 1
    off = []
    for s in range(width):
        o = float("-inf")
        for snk in sinks:
            o = max(o, b[topo_pos[snk] * width + s])
        theta = max(theta, -o)
        off.append(o)
    margin = (bmag + 1.0) * 1e-9
    return {"delta": b_prev, "busy_delta": busy_delta,
            "theta": theta + margin, "off": off}


def build_template(n_nodes, deps, topo, sinks, durations, overlap, width,
                   entry_prev_dur, entry_any_prev):
    """Transcription of fastpath::build_template (overlap pre-clamped)."""
    dur, cut, depidx, dep_off, slot = [], [], [], [0], []
    topo_pos = [0] * n_nodes
    for i, n in enumerate(topo):
        topo_pos[n] = i
    prev_dur = entry_prev_dur
    for node in topo:
        d = durations[node]
        for s in range(width):
            cut.append(overlap * min(prev_dur, d))
            dur.append(d)
            for p in deps[node]:
                depidx.append(s * n_nodes + p)
            dep_off.append(len(depidx))
            slot.append(s * n_nodes + node)
            prev_dur = d
    steady = _steady_info(
        n_nodes, deps, topo, width, dur, cut, topo_pos, sinks, entry_any_prev
    )
    return {"width": width, "n_nodes": n_nodes, "dur": dur, "cut": cut,
            "deps": depidx, "dep_off": dep_off, "slot": slot,
            "sinks": sinks, "steady": steady}


def _replay(tpl, t0, st, wfin, finish_times, lo):
    """Transcription of fastpath::replay; st = [array_free, any_prev,
    busy, makespan], finish written into finish_times[lo:lo+width]."""
    f, ap, busy, mk = st
    di = 0
    for j in range(len(tpl["dur"])):
        ready = t0
        dend = tpl["dep_off"][j + 1]
        while di < dend:
            ready = max(ready, wfin[tpl["deps"][di]])
            di += 1
        start = max(ready, f - tpl["cut"][j]) if ap else ready
        end = start + tpl["dur"][j]
        busy += end - (max(start, f) if ap else start)
        wfin[tpl["slot"][j]] = end
        f = end
        ap = True
        mk = max(mk, end)
    n_nodes = tpl["n_nodes"]
    for s in range(tpl["width"]):
        done = t0
        for snk in tpl["sinks"]:
            done = max(done, wfin[s * n_nodes + snk])
        finish_times[lo + s] = done
    st[0], st[1], st[2], st[3] = f, ap, busy, mk


def evaluate(n_nodes, deps, topo, durations, arrivals, batch, overlap,
             sinks, steady=True):
    """Transcription of fastpath::evaluate (the fastpath=True route;
    memoization is identity in Python — templates are pure functions of
    the key — so only the steady toggle is modeled). Returns
    (finish_times, makespan, busy, n_jobs, steady_windows)."""
    overlap = min(max(overlap, 0.0), MAX_OVERLAP)
    batch = max(batch, 1)
    n_img = len(arrivals)
    if n_img == 0:
        return [], 0.0, 0.0, 0, 0
    w0 = min(batch, n_img)
    n_full = n_img // batch
    tail_w = n_img % batch if n_img > batch else 0
    n_windows = -(-n_img // batch)
    d_last = durations[topo[-1]] if topo else 0.0

    tpl_first = build_template(
        n_nodes, deps, topo, sinks, durations, overlap, w0, 0.0, False
    )
    tpl_mid = (
        build_template(
            n_nodes, deps, topo, sinks, durations, overlap, batch, d_last, True
        )
        if n_full >= 2
        else None
    )
    tpl_tail = (
        build_template(
            n_nodes, deps, topo, sinks, durations, overlap, tail_w, d_last, True
        )
        if tail_w > 0
        else None
    )

    finish_times = [0.0] * n_img
    wfin = [0.0] * max(w0 * n_nodes, batch * n_nodes)
    st = [0.0, False, 0.0, 0.0]  # array_free, any_prev, busy, makespan
    steady_windows = 0
    tail_t0_max = None

    window = 0
    while window < n_windows:
        lo = window * batch
        hi = min(lo + batch, n_img)

        if (
            steady
            and window >= 1
            and window < n_full
            and n_full - window >= STEADY_MIN_WINDOWS
            and tpl_mid is not None
            and tpl_mid["steady"] is not None
        ):
            info = tpl_mid["steady"]
            if tail_t0_max is None:
                tail_t0_max = 0.0
                for a in arrivals[lo : n_full * batch]:
                    tail_t0_max = max(tail_t0_max, a)
            if st[0] - tail_t0_max >= info["theta"]:
                k = n_full - window
                for j in range(k):
                    f_in = st[0] + float(j) * info["delta"]
                    base = (window + j) * batch
                    for s in range(batch):
                        finish_times[base + s] = f_in + info["off"][s]
                kf = float(k)
                st[2] += kf * info["busy_delta"]
                st[0] += kf * info["delta"]
                st[3] = max(st[3], st[0])
                steady_windows = k
                window = n_full
                continue

        t0 = 0.0
        for a in arrivals[lo:hi]:
            t0 = max(t0, a)
        if window == 0:
            tpl = tpl_first
        elif hi - lo == batch:
            tpl = tpl_mid
        else:
            tpl = tpl_tail
        _replay(tpl, t0, st, wfin, finish_times, lo)
        window += 1

    return finish_times, st[3], st[2], n_img * n_nodes, steady_windows


def _random_fuzz_dag(rng, n):
    """Chain + random skip edges (the shape rust/tests/serve_fastpath.rs
    fuzzes); returns (deps, topo, sinks)."""
    deps = [[] for _ in range(n)]
    for i in range(1, n):
        deps[i].append(i - 1)
        if i >= 2 and rng.random() < 0.3:
            extra = rng.randrange(i - 1)
            if extra not in deps[i]:
                deps[i].append(extra)
    has_dependent = set()
    for ds in deps:
        has_dependent.update(ds)
    sinks = [i for i in range(n) if i not in has_dependent]
    return deps, list(range(n)), sinks


def fastpath_oracle():
    """Fast path vs exact engine: bit-equality off-steady, bounded error
    + correct (dis)engagement for the steady-state layer."""
    rng = random.Random(0xFA57)
    bit_cases = 0
    for trial in range(8000):
        n = rng.randint(1, 6)
        deps, topo, sinks = _random_fuzz_dag(rng, n)
        durations = [rng.uniform(1e-4, 1e-2) for _ in range(n)]
        arrivals = random_arrivals(rng, rng.randint(1, 30))
        batch = rng.randint(1, 7)
        overlap = rng.choice([0.0, 0.3, 0.6, 0.9, 0.95, 1.2])
        jobs, ft, makespan, busy = build(
            n, deps, topo, durations, arrivals, batch, overlap, sinks
        )
        for steady in (False, True):
            f_ft, f_mk, f_busy, f_jobs, f_sw = evaluate(
                n, deps, topo, durations, arrivals, batch, overlap, sinks,
                steady=steady,
            )
            ctx = (trial, n, batch, overlap, len(arrivals), steady)
            # small runs never extrapolate (< STEADY_MIN_WINDOWS windows)
            assert f_sw == 0, ctx
            assert f_jobs == len(jobs), ctx
            assert _bits(f_mk) == _bits(makespan), (ctx, f_mk, makespan)
            assert _bits(f_busy) == _bits(busy), (ctx, f_busy, busy)
            assert len(f_ft) == len(ft), ctx
            for a, b in zip(f_ft, ft):
                assert _bits(a) == _bits(b), (ctx, a, b)
        bit_cases += 1
    print(f"all {bit_cases} fast-path replay cases are bit-identical")

    # steady-state engagement: saturated closed-loop backlogs
    rng = random.Random(0x57EA)
    steady_cases = 0
    for trial in range(120):
        n = rng.randint(1, 5)
        deps, topo, sinks = _random_fuzz_dag(rng, n)
        durations = [rng.uniform(1e-4, 1e-2) for _ in range(n)]
        batch = rng.randint(1, 4)
        overlap = rng.choice([0.0, 0.5, 0.95])
        windows = STEADY_MIN_WINDOWS + rng.randint(1, 40)
        n_img = batch * windows + rng.choice([0, 1, batch - 1] if batch > 1 else [0])
        arrivals = [0.0] * n_img
        _, ft, makespan, busy = build(
            n, deps, topo, durations, arrivals, batch, overlap, sinks
        )
        f_ft, f_mk, f_busy, f_jobs, f_sw = evaluate(
            n, deps, topo, durations, arrivals, batch, overlap, sinks
        )
        ctx = (trial, n, batch, overlap, n_img)
        assert f_sw > 0, (ctx, "steady layer must engage on a closed loop")
        rel = lambda a, b: abs(a - b) / max(abs(b), 1e-300)
        assert rel(f_mk, makespan) < 1e-9, (ctx, f_mk, makespan)
        assert rel(f_busy, busy) < 1e-9, (ctx, f_busy, busy)
        assert f_jobs == n_img * n
        for a, b in zip(f_ft, ft):
            assert rel(a, b) < 1e-9, (ctx, a, b)
        steady_cases += 1

    # disengagement: arrivals that outrun the backlog keep the run on
    # the bit-exact path even at high R
    rng = random.Random(0xD15E)
    for trial in range(40):
        n = rng.randint(1, 4)
        deps, topo, sinks = _random_fuzz_dag(rng, n)
        durations = [rng.uniform(1e-4, 1e-3) for _ in range(n)]
        batch = rng.randint(1, 3)
        n_img = batch * (STEADY_MIN_WINDOWS + 10)
        gap = sum(durations) * batch * 2.0
        arrivals = [i * gap for i in range(n_img)]
        _, ft, makespan, busy = build(
            n, deps, topo, durations, arrivals, batch, 0.5, sinks
        )
        f_ft, f_mk, f_busy, _, f_sw = evaluate(
            n, deps, topo, durations, arrivals, batch, 0.5, sinks
        )
        assert f_sw == 0, (trial, "idle array must not extrapolate")
        assert _bits(f_mk) == _bits(makespan)
        assert _bits(f_busy) == _bits(busy)
        for a, b in zip(f_ft, ft):
            assert _bits(a) == _bits(b)
        steady_cases += 1
    print(f"all {steady_cases} steady-state cases engage/disengage correctly "
          f"within the error bound")


# --- dynamic-density transcription (rust/src/serve/density.rs and the
# dynamic twins in pipeline.rs / fastpath.rs) ---------------------------

DENSITY_SALT = 0x6D0DE15A
REQUEST_GAMMA = 0x9E3779B97F4A7C15
DENSITY_LEVELS = 16
DENSITY_FLOOR = 0.02
DENSITY_CEIL = 0.98
_DENSITY_STEP = (DENSITY_CEIL - DENSITY_FLOOR) / (DENSITY_LEVELS - 1)


def level_density(lv):
    """density::level_density."""
    return DENSITY_FLOOR + lv * _DENSITY_STEP


def quantize(d):
    """density::quantize — floor(x + 0.5) half-up, never round()."""
    lv = math.floor((d - DENSITY_FLOOR) / _DENSITY_STEP + 0.5)
    if lv <= 0:
        return 0
    return min(lv, DENSITY_LEVELS - 1)


def sample_levels(model, seed, request, scale, n_layers):
    """Transcription of DensityModel::sample_levels; `model` is
    ("uniform", lo, hi) | ("normal", mean, sigma) | ("bimodal", lo, hi, p)
    | ("trace", values)."""

    def scaled(i, raw):
        s = scale[i] if i < len(scale) else 1.0
        return quantize(min(max(raw * s, DENSITY_FLOOR), DENSITY_CEIL))

    if model[0] == "trace":
        tr = model[1]
        return [
            scaled(i, tr[(request * n_layers + i) % len(tr)])
            for i in range(n_layers)
        ]
    rng = Xoshiro(((seed ^ DENSITY_SALT) + request * REQUEST_GAMMA) & _M64)
    out = []
    for i in range(n_layers):
        if model[0] == "uniform":
            _, lo, hi = model
            raw = lo + (hi - lo) * rng.gen_f64()
        elif model[0] == "normal":
            _, mean, sigma = model
            raw = mean + sigma * rng.gen_normal()
        else:
            _, lo, hi, p = model
            raw = hi if rng.gen_f64() < p else lo
        out.append(scaled(i, raw))
    return out


def realized_rows(model, seed, requests, scale, wall):
    """density::realized_rows — rows[r*L + i] = wall[i][level]."""
    n_layers = len(wall)
    rows = []
    for r in range(requests):
        for i, lv in enumerate(sample_levels(model, seed, r, scale, n_layers)):
            rows.append(wall[i][lv])
    return rows


def build_dynamic(n_nodes, deps, topo, rows, arrivals, windows, overlap, sinks):
    """Transcription of PipelineSchedule::build_windows_dynamic (the
    exact dynamic engine — identical fold to `build`, but the duration
    is looked up per (request, node))."""
    overlap = min(max(overlap, 0.0), MAX_OVERLAP)
    n_img = len(arrivals)
    finish = [0.0] * (n_img * n_nodes)
    finish_times = [0.0] * n_img
    array_free = 0.0
    prev_dur = 0.0
    any_prev = False
    busy = 0.0
    makespan = 0.0
    n_jobs = 0
    for lo, hi in windows:
        window_ready = 0.0
        for a in arrivals[lo:hi]:
            window_ready = max(window_ready, a)
        for node in topo:
            for img in range(lo, hi):
                d = rows[img * n_nodes + node]
                ready = window_ready
                for p in deps[node]:
                    ready = max(ready, finish[img * n_nodes + p])
                if any_prev:
                    start = max(ready, array_free - overlap * min(prev_dur, d))
                else:
                    start = ready
                end = start + d
                busy += end - (max(start, array_free) if any_prev else start)
                finish[img * n_nodes + node] = end
                array_free = end
                prev_dur = d
                any_prev = True
                makespan = max(makespan, end)
                n_jobs += 1
        for img in range(lo, hi):
            done = window_ready
            for s in sinks:
                done = max(done, finish[img * n_nodes + s])
            finish_times[img] = done
    return finish_times, makespan, busy, n_jobs


def build_template_dyn(n_nodes, deps, topo, sinks, wdur, overlap, width,
                       entry_prev_dur, entry_any_prev):
    """Transcription of fastpath::build_template_dyn: per-window wave
    program over the realized duration block, now carrying its own
    `_steady_info` (the per-template max-plus recurrence the ensemble
    steady-state layer fills saturated windows with)."""
    dur, cut, depidx, dep_off, slot = [], [], [], [0], []
    topo_pos = [0] * n_nodes
    for i, n in enumerate(topo):
        topo_pos[n] = i
    prev_dur = entry_prev_dur
    for node in topo:
        for s in range(width):
            d = wdur[s * n_nodes + node]
            cut.append(overlap * min(prev_dur, d))
            dur.append(d)
            for p in deps[node]:
                depidx.append(s * n_nodes + p)
            dep_off.append(len(depidx))
            slot.append(s * n_nodes + node)
            prev_dur = d
    steady = _steady_info(
        n_nodes, deps, topo, width, dur, cut, topo_pos, sinks, entry_any_prev
    )
    return {"width": width, "n_nodes": n_nodes, "dur": dur, "cut": cut,
            "deps": depidx, "dep_off": dep_off, "slot": slot,
            "sinks": sinks, "steady": steady}


def _drive_dynamic(n_nodes, arrivals, windows, resolve, steady=True):
    """Transcription of fastpath::drive_dynamic — the shared dynamic
    scheduling loop behind both evaluate_windows_dynamic (rows) and
    evaluate_windows_streamed (RowStream): per-window template
    resolution chained through the entry execution state, with the
    per-template *ensemble* steady-state layer (a window is a pure
    F-shift whenever its own saturation threshold holds)."""
    n_w = len(windows)
    w_max = max((hi - lo for lo, hi in windows), default=0)
    n_img = len(arrivals)
    finish_times = [0.0] * n_img
    wfin = [0.0] * (w_max * n_nodes)
    st = [0.0, False, 0.0, 0.0]
    steady_windows = 0
    entry_prev_dur = 0.0
    entry_any_prev = False
    for w, (lo, hi) in enumerate(windows):
        t0 = 0.0
        for a in arrivals[lo:hi]:
            t0 = max(t0, a)
        tpl = resolve(lo, hi, entry_prev_dur, entry_any_prev)
        filled = False
        if (
            steady
            and w >= 1
            and n_w - w >= STEADY_MIN_WINDOWS
            and tpl["steady"] is not None
        ):
            info = tpl["steady"]
            if st[0] - t0 >= info["theta"]:
                for s in range(hi - lo):
                    finish_times[lo + s] = st[0] + info["off"][s]
                st[2] += info["busy_delta"]
                st[0] += info["delta"]
                st[3] = max(st[3], st[0])
                steady_windows += 1
                filled = True
        if not filled:
            _replay(tpl, t0, st, wfin, finish_times, lo)
        entry_prev_dur = tpl["dur"][-1] if tpl["dur"] else 0.0
        entry_any_prev = n_nodes > 0
    return finish_times, st[3], st[2], n_img * n_nodes, steady_windows


def evaluate_dynamic(n_nodes, deps, topo, rows, arrivals, windows, overlap,
                     sinks, steady=True):
    """Transcription of fastpath::evaluate_windows_dynamic (memoization
    is identity in Python — dynamic templates are pure functions of the
    realized duration block, which is exactly what `wave_key_dyn`
    keys)."""
    overlap = min(max(overlap, 0.0), MAX_OVERLAP)
    n_img = len(arrivals)
    if n_img == 0:
        return [], 0.0, 0.0, 0, 0

    def resolve(lo, hi, entry_prev_dur, entry_any_prev):
        wdur = rows[lo * n_nodes : hi * n_nodes]
        return build_template_dyn(
            n_nodes, deps, topo, sinks, wdur, overlap, hi - lo,
            entry_prev_dur, entry_any_prev,
        )

    return _drive_dynamic(n_nodes, arrivals, windows, resolve, steady)


def evaluate_streamed(n_nodes, deps, topo, sinks, model, seed, scale, wall,
                      arrivals, windows, overlap, steady=True, cache=None):
    """Transcription of fastpath::evaluate_windows_streamed — each
    window's levels and durations regenerated on demand from the salted
    per-request stream (RowStream::fill_window), templates resolved
    through the alphabet cache when `cache` is a dict (the Python
    spelling of wave_key_alphabet: within one run the DAG, overlap and
    interned wall table are fixed, so the key carries the varying parts
    — width, entry execution state, and the packed level block; a
    too-coarse key would diverge from the rows-based engine on some
    fuzzed case)."""
    overlap = min(max(overlap, 0.0), MAX_OVERLAP)
    n_img = len(arrivals)
    if n_img == 0:
        return [], 0.0, 0.0, 0, 0

    def resolve(lo, hi, entry_prev_dur, entry_any_prev):
        levels = []
        wdur = []
        for r in range(lo, hi):
            lv = sample_levels(model, seed, r, scale, n_nodes)
            levels.extend(lv)
            wdur.extend(wall[j][lv[j]] for j in range(n_nodes))
        if cache is None:
            return build_template_dyn(
                n_nodes, deps, topo, sinks, wdur, overlap, hi - lo,
                entry_prev_dur, entry_any_prev,
            )
        key = (hi - lo, _bits(entry_prev_dur), entry_any_prev, tuple(levels))
        tpl = cache.get(key)
        if tpl is None:
            tpl = build_template_dyn(
                n_nodes, deps, topo, sinks, wdur, overlap, hi - lo,
                entry_prev_dur, entry_any_prev,
            )
            cache[key] = tpl
        return tpl

    return _drive_dynamic(n_nodes, arrivals, windows, resolve, steady)


def _random_density_model(rng):
    kind = rng.randrange(4)
    if kind == 0:
        lo = rng.uniform(0.05, 0.5)
        return ("uniform", lo, lo + rng.uniform(0.0, 0.45))
    if kind == 1:
        return ("normal", rng.uniform(0.1, 0.7), rng.choice([0.0, 0.05, 0.15, 0.3]))
    if kind == 2:
        lo = rng.uniform(0.05, 0.3)
        return ("bimodal", lo, lo + rng.uniform(0.1, 0.6), rng.random())
    return ("trace", [rng.uniform(0.02, 0.98) for _ in range(rng.randint(1, 9))])


def _fixed_windows(n_img, batch):
    batch = max(batch, 1)
    out = []
    lo = 0
    while lo < n_img:
        hi = min(lo + batch, n_img)
        out.append((lo, hi))
        lo = hi
    return out


def dynamic_density_oracle():
    """Per-request density sampling + the dynamic scheduler pair."""
    # (a) sampling invariants, mirroring the Rust unit tests: per-request
    # determinism (resharding-stable — request r's vector is a pure
    # function of (model, seed, r, scale)), band respect under
    # quantization, two-point bimodal support, decay-scale monotonicity.
    m = ("uniform", 0.1, 0.6)
    assert sample_levels(m, 42, 7, [], 5) == sample_levels(m, 42, 7, [], 5)
    assert sample_levels(m, 42, 7, [], 5) != sample_levels(m, 42, 8, [], 5)
    assert sample_levels(m, 42, 7, [], 5) != sample_levels(m, 43, 7, [], 5)
    for r in range(200):
        for lv in sample_levels(("uniform", 0.2, 0.5), 1, r, [], 4):
            assert 0.15 <= level_density(lv) <= 0.55, lv
    seen = set()
    for r in range(300):
        seen.update(sample_levels(("bimodal", 0.1, 0.8, 0.3), 9, r, [], 3))
    assert seen == {quantize(0.1), quantize(0.8)}, seen
    levels = sample_levels(("uniform", 0.5, 0.5001), 3, 0, [1.0, 0.6, 0.36, 0.216], 4)
    assert all(b <= a for a, b in zip(levels, levels[1:])), levels
    assert quantize(0.0) == 0 and quantize(DENSITY_FLOOR) == 0
    assert quantize(1.0) == DENSITY_LEVELS - 1
    cases = 7

    # (b) the acceptance gate: exact dynamic engine vs rows-based fast
    # path vs streamed fast path (alphabet cache on AND off),
    # bit-identical across >= 1k sampled-density cases (chain and skip
    # DAGs, every model family, fixed-batch and SLO window partitions).
    # Small R keeps the ensemble steady layer structurally disengaged
    # (< STEADY_MIN_WINDOWS remaining windows), so everything here is
    # exact replay.
    rng = random.Random(0xD94517)
    for trial in range(4000):
        n = rng.randint(1, 6)
        deps, topo, sinks = _random_fuzz_dag(rng, n)
        model = _random_density_model(rng)
        scale = (
            [rng.uniform(0.2, 1.0) for _ in range(n)]
            if rng.random() < 0.3
            else []
        )
        wall = [
            sorted(rng.uniform(1e-4, 1e-2) for _ in range(DENSITY_LEVELS))
            for _ in range(n)
        ]
        seed = rng.randrange(1 << 32)
        requests = rng.randint(1, 30)
        arrivals = random_arrivals(rng, requests)
        rows = realized_rows(model, seed, requests, scale, wall)
        batch = rng.randint(1, 7)
        overlap = rng.choice([0.0, 0.3, 0.6, 0.9, 0.95, 1.2])
        if rng.random() < 0.5:
            windows = _fixed_windows(requests, batch)
        else:
            slo = rng.choice([0.0, 1e-3, 5e-3, float("inf")])
            windows = slo_windows(arrivals, batch, slo)
        ft, mk, busy, n_jobs = build_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        f_ft, f_mk, f_busy, f_jobs, f_sw = evaluate_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        ctx = (trial, n, model[0], batch, overlap, requests)
        assert f_sw == 0, (ctx, "small dynamic run must not extrapolate")
        assert f_jobs == n_jobs, ctx
        assert _bits(f_mk) == _bits(mk), (ctx, f_mk, mk)
        assert _bits(f_busy) == _bits(busy), (ctx, f_busy, busy)
        for a, b in zip(f_ft, ft):
            assert _bits(a) == _bits(b), (ctx, a, b)
        # the streamed engine (levels regenerated per window) must match
        # the rows-based one bit for bit, with the alphabet cache on and
        # off — a cache key missing any template-determining input would
        # surface here as a divergence on some sampled case
        for cache in (None, {}):
            s_ft, s_mk, s_busy, s_jobs, s_sw = evaluate_streamed(
                n, deps, topo, sinks, model, seed, scale, wall,
                arrivals, windows, overlap, cache=cache,
            )
            sctx = (ctx, "cached" if cache is not None else "uncached")
            assert s_sw == f_sw and s_jobs == f_jobs, sctx
            assert _bits(s_mk) == _bits(f_mk), (sctx, s_mk, f_mk)
            assert _bits(s_busy) == _bits(f_busy), (sctx, s_busy, f_busy)
            for a, b in zip(s_ft, f_ft):
                assert _bits(a) == _bits(b), (sctx, a, b)
        # dynamic chain floor: a request can never finish before its own
        # realized work, window-gated by its admission
        if all(len(d) <= 1 for d in deps):
            for (lo, hi) in windows:
                gate = max(arrivals[lo:hi])
                for img in range(lo, hi):
                    own = 0.0
                    for node in topo:
                        own += rows[img * n + node]
                    assert ft[img] >= gate + own - 1e-12, (ctx, img)
        cases += 1

    # (c) degenerate anchor: every row equal to the static duration
    # vector reproduces the static engines bit for bit (the Rust suite
    # locks the same identity; here it pins the transcriptions to each
    # other, so a drift in either dynamic twin is caught immediately).
    rng = random.Random(0xD94518)
    for trial in range(1000):
        n = rng.randint(1, 5)
        deps, topo, sinks = _random_fuzz_dag(rng, n)
        durations = [rng.uniform(1e-4, 1e-2) for _ in range(n)]
        requests = rng.randint(1, 20)
        arrivals = random_arrivals(rng, requests)
        rows = durations * requests
        batch = rng.randint(1, 5)
        overlap = rng.choice([0.0, 0.5, 0.95])
        windows = _fixed_windows(requests, batch)
        _, s_ft, s_mk, s_busy = build(
            n, deps, topo, durations, arrivals, batch, overlap, sinks
        )
        d_ft, d_mk, d_busy, _ = build_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        f_ft, f_mk, f_busy, _, _ = evaluate_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        ctx = (trial, n, batch, overlap, requests)
        assert _bits(d_mk) == _bits(s_mk) == _bits(f_mk), ctx
        assert _bits(d_busy) == _bits(s_busy) == _bits(f_busy), ctx
        for a, b, c in zip(d_ft, s_ft, f_ft):
            assert _bits(a) == _bits(b) == _bits(c), (ctx, a, b, c)
        cases += 1

    # (d) the ensemble steady-state layer: a saturated closed-loop
    # backlog deep enough to clear STEADY_MIN_WINDOWS must fill windows
    # in closed form (steady_windows > 0) within the documented <1e-9
    # relative bound, for both the rows-based and streamed engines; the
    # steady=False opt-out and spread (unsaturated) arrivals must stay
    # bit-exact against the exact engine even at the same depth.
    rng = random.Random(0xD94519)
    for trial in range(60):
        n = rng.randint(1, 4)
        deps, topo, sinks = _random_fuzz_dag(rng, n)
        model = _random_density_model(rng)
        scale = []
        wall = [
            sorted(rng.uniform(1e-4, 1e-2) for _ in range(DENSITY_LEVELS))
            for _ in range(n)
        ]
        seed = rng.randrange(1 << 32)
        batch = rng.randint(1, 3)
        overlap = rng.choice([0.0, 0.5, 0.95])
        n_windows = STEADY_MIN_WINDOWS + rng.randint(2, 20)
        requests = batch * n_windows
        rows = realized_rows(model, seed, requests, scale, wall)
        windows = _fixed_windows(requests, batch)
        rel = lambda a, b: abs(a - b) / max(abs(b), 1e-300)
        ctx = (trial, n, model[0], batch, overlap, requests)

        # saturated: everything queued at t = 0
        arrivals = [0.0] * requests
        ft, mk, busy, _ = build_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        f_ft, f_mk, f_busy, _, f_sw = evaluate_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        assert f_sw > 0, (ctx, "ensemble steady must engage on a backlog")
        assert rel(f_mk, mk) < 1e-9, (ctx, f_mk, mk)
        assert rel(f_busy, busy) < 1e-9, (ctx, f_busy, busy)
        for a, b in zip(f_ft, ft):
            assert rel(a, b) < 1e-9, (ctx, a, b)
        s_ft, s_mk, s_busy, _, s_sw = evaluate_streamed(
            n, deps, topo, sinks, model, seed, scale, wall,
            arrivals, windows, overlap, cache={},
        )
        assert s_sw == f_sw, (ctx, s_sw, f_sw)
        assert _bits(s_mk) == _bits(f_mk), (ctx, s_mk, f_mk)
        assert _bits(s_busy) == _bits(f_busy), ctx
        for a, b in zip(s_ft, f_ft):
            assert _bits(a) == _bits(b), (ctx, a, b)
        o_ft, o_mk, o_busy, _, o_sw = evaluate_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks,
            steady=False,
        )
        assert o_sw == 0, ctx
        assert _bits(o_mk) == _bits(mk) and _bits(o_busy) == _bits(busy), ctx
        for a, b in zip(o_ft, ft):
            assert _bits(a) == _bits(b), (ctx, a, b)
        cases += 1

        # spread: arrivals outrun the array, the gate never passes and
        # the whole run stays bit-exact at full depth
        gap = max(max(r for r in rows), 1e-6) * (n + batch) * 2.0
        arrivals = [i * gap for i in range(requests)]
        ft, mk, busy, _ = build_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        f_ft, f_mk, f_busy, _, f_sw = evaluate_dynamic(
            n, deps, topo, rows, arrivals, windows, overlap, sinks
        )
        assert f_sw == 0, (ctx, "idle array must not extrapolate")
        assert _bits(f_mk) == _bits(mk) and _bits(f_busy) == _bits(busy), ctx
        for a, b in zip(f_ft, ft):
            assert _bits(a) == _bits(b), (ctx, a, b)
        cases += 1

    print(f"all {cases} dynamic-density oracle cases are bit-identical "
          f"(exact vs rows vs streamed fast path, ensemble steady, "
          f"static anchor)")


# --- analytic backend transcriptions (rust/src/baseline/*.rs) ---------


def naive_cycles(m, k, n, rows, cols):
    """baseline::naive::layer_cost mac_cycles (integer arithmetic)."""
    row_tiles = -(-m // rows)
    col_tiles = -(-n // cols)
    per_tile = k + (rows - 1) + (cols - 1) + rows
    return row_tiles * col_tiles * per_tile


def _frag(d):
    nz = max(16.0 * d, 1e-9)
    slots = math.ceil(nz / 4.0) * 4.0
    return nz / slots


def scnn_cycles(dense_macs, df, dw):
    """baseline::scnn::cost mac_cycles (f64-faithful transcription)."""
    must = math.ceil(float(dense_macs) * df * dw)
    util = 0.79 * _frag(df) * _frag(dw)
    return int(math.ceil(float(must) / (1024.0 * util)))


def sparten_cycles(dense_macs, df, dw):
    """baseline::sparten::cost mac_cycles."""
    must = math.ceil(float(dense_macs) * df * dw)
    return int(math.ceil(float(must) / (1024.0 * 0.92)))


def gating_cycles(dense_macs, df, dw, policy):
    """baseline::gating::cost mac_cycles; policy in
    {dense, gate, skipf, skipw, skipb}."""
    frac = {
        "dense": 1.0,
        "gate": 1.0,
        "skipf": df,
        "skipw": dw,
        "skipb": df * dw,
    }[policy]
    return int(max(math.ceil(float(dense_macs) * frac / 1024.0), 1))


def analytic_backend_case():
    """The trait-dispatch oracle: analytic per-layer walls through the
    schedule must fold exactly, and the golden cycles must hold."""
    # golden closed forms (rust/tests/baseline_golden.rs)
    assert naive_cycles(16, 16, 4, 8, 8) == 76
    assert scnn_cycles(1_000_000, 0.5, 0.5) == 310
    assert sparten_cycles(1_000_000, 0.5, 0.5) == 266
    assert gating_cycles(1_024_000, 0.5, 0.25, "skipf") == 500
    assert gating_cycles(1_024_000, 0.5, 0.25, "dense") == 1000

    rng = random.Random(31337)
    cases = 0
    for trial in range(3000):
        n_layers = rng.randint(1, 10)
        df = rng.choice([0.1, 0.25, 0.38, 0.5, 0.75, 1.0])
        dw = rng.choice([0.2, 0.34, 0.5, 1.0])
        family = rng.choice(["naive", "scnn", "sparten", "gate", "skipf", "skipw"])
        cycles = []
        for _ in range(n_layers):
            m = rng.randint(1, 4096)
            k = rng.randint(1, 2048)
            n = rng.randint(1, 512)
            if family == "naive":
                cycles.append(naive_cycles(m, k, n, 16, 16))
            elif family == "scnn":
                cycles.append(scnn_cycles(m * k * n, df, dw))
            elif family == "sparten":
                cycles.append(sparten_cycles(m * k * n, df, dw))
            else:
                cycles.append(gating_cycles(m * k * n, df, dw, family))
        durations = [c / CLK for c in cycles]
        topo, deps = topo_chain(n_layers)
        sinks = [n_layers - 1]

        # (a) the backend-equivalence contract: single request, batch 1,
        # overlap 0 -> makespan is the exact left-fold of the walls
        _, ft, makespan, _ = build(
            n_layers, deps, topo, durations, [0.0], 1, 0.0, sinks
        )
        fold = 0.0
        for d in durations:
            fold = fold + d
        assert makespan == fold, (trial, family, makespan, fold)
        assert ft[0] == fold

        # (b) the schedule invariants hold for analytic durations under
        # batching/overlap too (the cluster/serve stack sees no
        # difference between backends)
        arrivals = random_arrivals(rng, rng.randint(1, 12))
        batch = rng.randint(1, 5)
        overlap = rng.choice([0.0, 0.5, 0.95])
        _, _, m2, busy = build(
            n_layers, deps, topo, durations, arrivals, batch, overlap, sinks
        )
        chain = critical_path_chain(durations)
        lower = max(a + chain for a in arrivals)
        upper = serial_makespan(durations, arrivals, batch)
        eps = abs(upper) * 1e-12 + 1e-15
        assert lower - eps <= m2 <= upper + eps, (trial, family, m2, lower, upper)
        assert busy <= m2 + 1e-12
        cases += 1
    print(f"all {cases} analytic-backend oracle cases fold exactly")


def random_arrivals(rng, r):
    if rng.random() < 0.3:
        return [0.0] * r
    t = 0.0
    out = [0.0]
    for _ in range(r - 1):
        t += rng.uniform(0, 2e-2)
        out.append(t)
    return out


# ---------------------------------------------------------------------------
# Traffic-engine oracle: transcription of rust/src/util/rng.rs and the
# arrival generators + SLO window closure of rust/src/serve/traffic.rs
# (and Arrivals::open_loop in rust/src/serve/workload.rs), checked
# against the bit goldens the Rust tests lock and an independently
# formulated admission-queue oracle.
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1


def _rotl64(x, k):
    return ((x << k) | (x >> (64 - k))) & _M64


class Xoshiro:
    """Transcription of util::rng::Rng (SplitMix64 -> xoshiro256++)."""

    def __init__(self, seed):
        st = seed & _M64
        s = []
        for _ in range(4):
            st = (st + 0x9E3779B97F4A7C15) & _M64
            z = st
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl64((s[0] + s[3]) & _M64, 23) + s[0]) & _M64
        t = (s[1] << 17) & _M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl64(s[3], 45)
        return result

    def gen_f64(self):
        # (next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64): the
        # int -> float conversion is exact (53 bits) and the scale is a
        # power of two, so this matches the Rust expression bit for bit
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_normal(self):
        # Irwin–Hall(6): the same in-order f64 left-fold as Rust's
        # `(0..6).map(gen_f64).sum::<f64>() - 3.0` then `/ 0.7071`
        s = 0.0
        for _ in range(6):
            s = s + self.gen_f64()
        return (s - 3.0) / 0.7071


POISSON_SALT = 0x7A1E0F5D
MMPP_SALT = 0x3C8B52A7
DIURNAL_SALT = 0xD1A24E63
DIURNAL_PROFILE = [0.4, 0.7, 1.3, 1.6]
DIURNAL_SEG_GAPS = 64.0


def open_loop(requests, rate, seed):
    """Transcription of Arrivals::open_loop (uniform-jitter baseline)."""
    if rate <= 0.0 or requests == 0:
        return [0.0] * requests
    rng = Xoshiro(seed ^ 0x5E7EA11A)
    mean_gap = 1.0 / rate
    t = 0.0
    times = [0.0]
    for _ in range(1, requests):
        t += mean_gap * (0.5 + rng.gen_f64())
        times.append(t)
    return times


def poisson_arrivals(requests, rate, seed):
    """Transcription of the ArrivalProcess::Poisson arm."""
    if rate <= 0.0 or requests == 0:
        return [0.0] * requests
    rng = Xoshiro(seed ^ POISSON_SALT)
    mean_gap = 1.0 / rate
    t = 0.0
    times = [0.0]
    for _ in range(1, requests):
        t += -mean_gap * math.log(1.0 - rng.gen_f64())
        times.append(t)
    return times


def mmpp_arrivals(requests, rate, burst, switch, seed):
    """Transcription of the ArrivalProcess::Mmpp arm (two-state MMPP,
    memoryless redraw at every state switch)."""
    if rate <= 0.0 or requests == 0:
        return [0.0] * requests
    rng = Xoshiro(seed ^ MMPP_SALT)
    lam = [rate * (2.0 - burst), rate * burst]
    t = 0.0
    state = 1  # start in the burst state
    next_switch = -math.log(1.0 - rng.gen_f64()) / switch
    times = [0.0]
    for _ in range(1, requests):
        while True:
            gap = -math.log(1.0 - rng.gen_f64()) / lam[state]
            if t + gap <= next_switch:
                t += gap
                break
            t = next_switch
            state = 1 - state
            next_switch = t + -math.log(1.0 - rng.gen_f64()) / switch
        times.append(t)
    return times


def diurnal_arrivals(requests, rate, seed):
    """Transcription of the ArrivalProcess::Diurnal arm (piecewise-
    constant thinning with an explicit segment counter)."""
    if rate <= 0.0 or requests == 0:
        return [0.0] * requests
    rng = Xoshiro(seed ^ DIURNAL_SALT)
    seg_len = DIURNAL_SEG_GAPS / rate
    t = 0.0
    seg = 0
    times = [0.0]
    for _ in range(1, requests):
        while True:
            lam = rate * DIURNAL_PROFILE[seg % len(DIURNAL_PROFILE)]
            seg_end = float(seg + 1) * seg_len
            gap = -math.log(1.0 - rng.gen_f64()) / lam
            if t + gap <= seg_end:
                t += gap
                break
            t = seg_end
            seg += 1
        times.append(t)
    return times


def slo_windows(arrivals, batch, slo):
    """Transcription of serve::traffic::windows (two-pointer greedy)."""
    batch = max(batch, 1)
    n = len(arrivals)
    out = []
    lo = 0
    while lo < n:
        hi = lo + 1
        while hi < n and hi - lo < batch and arrivals[hi] - arrivals[lo] <= slo:
            hi += 1
        out.append((lo, hi))
        lo = hi
    return out


def admission_queue_oracle(arrivals, batch, slo):
    """Independent window-closure formulation: an online dispatcher
    watches arrivals one at a time and flushes its queue the moment the
    next admission would overfill the batch or blow the oldest queued
    request's batch-forming budget. Same policy as `windows`, derived
    as an event loop instead of a two-pointer scan."""
    batch = max(batch, 1)
    wins = []
    start = None
    for i, a in enumerate(arrivals):
        if start is None:
            start = i
        elif i - start == batch or a - arrivals[start] > slo:
            wins.append((start, i))
            start = i
    if start is not None:
        wins.append((start, len(arrivals)))
    return wins


def traffic_oracle():
    """Arrival-generator and window-closure oracle for the traffic
    engine (rust/src/serve/traffic.rs, rust/tests/traffic_properties.rs)."""
    cases = 0

    # (a) cross-language anchor: open_loop(100, 10, 7) is pure +/*
    # arithmetic on exactly-representable uniforms, so the transcription
    # must hit the very bits rust/src/serve/workload.rs locks in
    # `open_loop_seed7_sequence_is_bit_stable`.
    golden = {
        0: 0x0000000000000000,
        1: 0x3FB8A8FB04B1889C,
        2: 0x3FC43A13FB29A054,
        3: 0x3FD0FDFB140FEF90,
        4: 0x3FD49AF6A9D2B5A5,
        99: 0x4023F378F183C485,
    }
    ts = open_loop(100, 10.0, 7)
    for i, bits in golden.items():
        got = struct.unpack("<Q", _bits(ts[i]))[0]
        assert got == bits, (i, hex(got), hex(bits))
    cases += len(golden)

    # (b) generator invariants + seed determinism, bit-compared through
    # struct.pack: same seed -> identical byte strings, different seed
    # -> different timeline; t[0] = 0; sorted; finite.
    gens = [
        ("uniform", lambda n, s: open_loop(n, 1000.0, s)),
        ("poisson", lambda n, s: poisson_arrivals(n, 1000.0, s)),
        ("mmpp", lambda n, s: mmpp_arrivals(n, 1000.0, 1.8, 20.0, s)),
        ("diurnal", lambda n, s: diurnal_arrivals(n, 1000.0, s)),
    ]
    for name, gen in gens:
        for seed in (3, 7, 11, 42, 0xBEEF):
            for n in (1, 2, 17, 256):
                a = gen(n, seed)
                b = gen(n, seed)
                pa = b"".join(_bits(x) for x in a)
                assert pa == b"".join(_bits(x) for x in b), (name, seed, n)
                assert a[0] == 0.0 and len(a) == n, (name, seed, n)
                assert all(y >= x for x, y in zip(a, a[1:])), (name, seed, n)
                assert all(math.isfinite(x) for x in a), (name, seed, n)
                if n > 2:
                    c = gen(n, seed + 1)
                    assert pa != b"".join(_bits(x) for x in c), (name, seed, n)
                cases += 1
    # rate <= 0 / zero requests degenerate to the closed batch
    assert open_loop(5, 0.0, 7) == [0.0] * 5
    assert poisson_arrivals(5, -1.0, 7) == [0.0] * 5
    assert mmpp_arrivals(0, 1000.0, 1.8, 20.0, 7) == []
    assert diurnal_arrivals(5, 0.0, 7) == [0.0] * 5
    cases += 4

    # (c) one-shot law checks at n = 20k (the Rust statistical gates in
    # traffic_properties.rs run at 50k with +/-5%; this is the sanity
    # tier, not the gate): empirical mean rate near the declared rate,
    # and MMPP visibly over-dispersed relative to Poisson.
    n, rate = 20_000, 1000.0
    for name, gen in gens:
        a = gen(n, 7)
        mean_gap = a[-1] / (n - 1)
        assert abs(mean_gap * rate - 1.0) < 0.05, (name, mean_gap)
        cases += 1

    def dispersion(times, bin_w):
        nb = int(times[-1] / bin_w)
        counts = [0] * nb
        for t in times:
            k = int(t / bin_w)
            if k < nb:
                counts[k] += 1
        mean = sum(counts) / nb
        var = sum((c - mean) ** 2 for c in counts) / nb
        return var / mean

    iod_poisson = dispersion(poisson_arrivals(n, rate, 7), 100.0 / rate)
    iod_mmpp = dispersion(mmpp_arrivals(n, rate, 1.8, 20.0, 7), 100.0 / rate)
    assert 0.5 < iod_poisson < 2.0, iod_poisson
    assert iod_mmpp > 3.0 * iod_poisson, (iod_mmpp, iod_poisson)
    cases += 2

    # (d) window closure: the `windows` transcription against the
    # independent admission-queue oracle, plus the partition invariants,
    # across every generator family and pathological timelines.
    rng = random.Random(0x57AFF1C)
    for trial in range(6000):
        kind = rng.randrange(6)
        m = rng.randint(1, 96)
        seed = rng.randrange(1 << 32)
        if kind == 0:
            arrivals = open_loop(m, 1000.0, seed)
        elif kind == 1:
            arrivals = poisson_arrivals(m, 1000.0, seed)
        elif kind == 2:
            arrivals = mmpp_arrivals(m, 1000.0, 1.8, 20.0, seed)
        elif kind == 3:
            arrivals = diurnal_arrivals(m, 1000.0, seed)
        elif kind == 4:
            arrivals = [0.0] * m  # closed batch: all queued at t = 0
        else:
            # duplicate-heavy: plateaus stress the tie-break (<= slo)
            arrivals = sorted(
                round(x, 3) for x in poisson_arrivals(m, 1000.0, seed)
            )
        batch = rng.randint(1, 8)
        slo = rng.choice(
            [0.0, 1e-9, 0.5e-3, 1.0e-3, 5.0e-3, 0.1, float("inf")]
        )
        w = slo_windows(arrivals, batch, slo)
        ctx = (trial, kind, m, batch, slo)
        assert w == admission_queue_oracle(arrivals, batch, slo), ctx
        # tiling partition of 0..m
        assert w[0][0] == 0 and w[-1][1] == m, ctx
        for (_, a_hi), (b_lo, _) in zip(w, w[1:]):
            assert a_hi == b_lo, ctx
        bmax = max(batch, 1)
        for lo, hi in w:
            assert 1 <= hi - lo <= bmax, ctx
            # budget: no admitted request waits past slo for its window
            if hi - lo > 1:
                assert arrivals[hi - 1] - arrivals[lo] <= slo, ctx
            # maximality: the window closed for a reason
            if hi < m:
                assert hi - lo == bmax or arrivals[hi] - arrivals[lo] > slo, ctx
        if math.isinf(slo):
            fixed = [(i, min(i + bmax, m)) for i in range(0, m, bmax)]
            assert w == fixed, ctx
        cases += 1

    print(f"all {cases} traffic-engine oracle cases match (goldens, laws, windows)")


def main():
    rng = random.Random(98765)
    cases = 0
    for trial in range(30000):
        length = rng.randint(1, 12)
        durations = [rng.uniform(1e-6, 1e-2) for _ in range(length)]
        topo, deps = topo_chain(length)
        sinks = [length - 1]
        arrivals = random_arrivals(rng, rng.randint(1, 24))
        batch = rng.randint(1, 9)
        overlap = rng.choice([0.0, 0.2, 0.5, 0.9, 0.95, 1.2])
        jobs, ft, makespan, busy = build(
            length, deps, topo, durations, arrivals, batch, overlap, sinks
        )
        chain = critical_path_chain(durations)
        lower = max(a + chain for a in arrivals)
        upper = serial_makespan(durations, arrivals, batch)
        eps = abs(upper) * 1e-12 + 1e-15
        ctx = (trial, length, batch, overlap, len(arrivals))
        assert makespan >= lower - eps, (ctx, makespan, lower)
        assert makespan <= upper + eps, (ctx, makespan, upper)
        for a, b in zip(jobs, jobs[1:]):
            assert b[3] > a[3], (ctx, a, b)
        assert busy <= makespan + 1e-12, ctx
        assert busy <= sum(durations) * len(arrivals) + 1e-9, ctx
        for f, a in zip(ft, arrivals):
            assert f - a >= chain - 1e-12, (ctx, f, a, chain)
        if overlap == 0.0:
            assert abs(makespan - upper) < eps, (ctx, makespan, upper)
        if batch == 1 and overlap == 0.0 and len(arrivals) == 1:
            s = 0.0
            for d in durations:
                s = s + d
            assert makespan == s, (ctx, makespan, s)
        cases += 1

    # overlap monotonicity
    rng = random.Random(424242)
    for trial in range(5000):
        length = rng.randint(1, 8)
        durations = [rng.uniform(1e-6, 1e-2) for _ in range(length)]
        topo, deps = topo_chain(length)
        arrivals = random_arrivals(rng, rng.randint(1, 12))
        batch = rng.randint(1, 6)
        prev = float("inf")
        for ov in [0.0, 0.2, 0.4, 0.6, 0.8, 0.95]:
            _, _, m, _ = build(
                length, deps, topo, durations, arrivals, batch, ov, [length - 1]
            )
            assert m <= prev + 1e-12, (trial, ov, m, prev)
            prev = m
        cases += 1

    # diamond DAG: 0 -> {1, 2} -> 3 (general-DAG dependency respect)
    rng = random.Random(777)
    deps = [[], [0], [0], [1, 2]]
    topo = [0, 1, 2, 3]
    for trial in range(3000):
        durations = [rng.uniform(1e-4, 1e-2) for _ in range(4)]
        arrivals = sorted(rng.uniform(0, 5e-2) for _ in range(rng.randint(1, 10)))
        arrivals[0] = 0.0
        batch = rng.randint(1, 4)
        overlap = rng.choice([0.0, 0.5, 0.95])
        jobs, ft, makespan, busy = build(
            4, deps, topo, durations, arrivals, batch, overlap, [3]
        )
        cp = durations[0] + max(durations[1], durations[2]) + durations[3]
        lower = max(a + cp for a in arrivals)
        upper = serial_makespan(durations, arrivals, batch)
        assert makespan >= lower - 1e-12, (trial, makespan, lower)
        assert makespan <= upper + abs(upper) * 1e-12 + 1e-15, (trial, makespan, upper)
        if overlap == 0.0:
            # total-work serial reference: exact on DAGs too
            assert abs(makespan - upper) < abs(upper) * 1e-12 + 1e-15
        fin = {}
        for img, node, s, e in jobs:
            for p in deps[node]:
                assert s >= fin[(img, p)] - 1e-15, (trial, img, node, s)
            fin[(img, node)] = e
        cases += 1

    print(f"all {cases} serve-pipeline fuzz cases satisfy the schedule invariants")
    analytic_backend_case()
    fastpath_oracle()
    dynamic_density_oracle()
    traffic_oracle()


if __name__ == "__main__":
    main()
