#!/usr/bin/env bash
# Tier-1 verification gate: release build, full test suite (unit +
# integration + doc tests), a compile-check of every bench target (they
# are plain binaries with harness = false, so --no-run is the build-only
# mode), and a warning-free rustdoc build (EXPERIMENTS.md §Docs).
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo test --doc -q
cargo bench --no-run
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "tier1 OK"
