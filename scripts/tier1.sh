#!/usr/bin/env bash
# Tier-1 verification gate: release build, full test suite (unit +
# integration + doc tests) under BOTH default parallelism and a single
# test thread. The serial pass pins test-ORDER determinism: tests share
# process-wide state (the tile memo cache), so the suite must pass under
# any interleaving — a test that only passes when a neighbour warmed or
# missed the cache fails one of the two runs. (Simulator WORKER-count
# invariance is enforced inside the suite itself:
# sweep::runner::tests::serial_and_sharded_results_identical and the
# memo on/off equivalence tests.) Then: a compile-check of every bench
# target (plain binaries with harness = false, so --no-run is the
# build-only mode), a warning-free rustdoc build, and — when the clippy
# component is installed — a warning-free clippy pass over every target
# (EXPERIMENTS.md §Docs / §Tier-1). Finally, when python3 is available,
# the scheduler transcription fuzzes (scripts/fuzz_serve_pipeline.py,
# scripts/fuzz_cluster.py) re-check the serving and cluster schedule
# invariants against their Python oracles — including the serving
# fast-path oracle (serve/fastpath.rs transcription: wave-template
# replay bit-identical to the exact engine, steady-state layer bounded
# and correctly gated).
#
# CI (.github/workflows/ci.yml) invokes THIS script for its build/test
# jobs, so the CI gate and the local gate cannot drift.
set -euo pipefail
cd "$(dirname "$0")/../rust"

# Structural memory gate: the serve/cluster hot paths must stream
# dynamic-density rows window-by-window (serve::density::RowStream,
# O(batch·L) scratch) — a realized_rows(...) call reappearing in any of
# these files would silently reintroduce the O(R·L) materialization.
# Doc references ([`...realized_rows`]) carry no '(' and don't trip it.
if grep -n "realized_rows(" \
    src/serve/mod.rs src/serve/traffic.rs src/serve/fastpath.rs \
    src/cluster/mod.rs src/cluster/schedule.rs; then
    echo "tier1: realized_rows materialization is back on a hot path" >&2
    exit 1
fi

cargo build --release
cargo test -q
cargo test -q -- --test-threads=1
cargo test --doc -q
cargo bench --no-run
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "tier1: cargo clippy unavailable in this toolchain; lint pass skipped"
fi
if command -v python3 >/dev/null 2>&1; then
    python3 ../scripts/fuzz_serve_pipeline.py
    python3 ../scripts/fuzz_cluster.py
else
    echo "tier1: python3 unavailable; transcription fuzz oracles skipped"
fi
echo "tier1 OK"
