#!/usr/bin/env bash
# Tier-1 verification gate: release build, full test suite, and a
# compile-check of every bench target (they are plain binaries with
# harness = false, so --no-run is the build-only mode).
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo bench --no-run
echo "tier1 OK"
