"""Python transcription of rust/src/sim/{pe,fifo,reference,array}.rs to
fuzz the event-driven scheduler against the reference sweep.

Faithful to the Rust CODE as written (not to intent): any logic bug in the
event engine should show up as a stats divergence here.
"""
import heapq
import random
from collections import deque

EOG = 1 << 12
EOK = 1 << 13
TAG16 = 1 << 14
HI = 1 << 15
INF = None  # infinite cap

def tok(value, offset, eog=False, eok=False, tag16=False, hi=False):
    t = (value & 0xFF) | (offset << 8)
    if eog: t |= EOG
    if eok: t |= EOK
    if tag16: t |= TAG16
    if hi: t |= HI
    return t

def t_value(t): return t & 0xFF
def t_offset(t): return (t >> 8) & 0xF
def t_eog(t): return bool(t & EOG)
def t_tag16(t): return bool(t & TAG16)
def t_hi(t): return bool(t & HI)
def t_placeholder(t): return (t & 0xFF) == 0


class Fifo:
    def __init__(self, cap):
        self.cap = cap  # None = infinite
        self.q = deque()

    def is_empty(self): return not self.q
    def has_space(self):
        return self.cap is None or len(self.q) < self.cap
    def push(self, v):
        assert self.has_space() or self.cap is None
        self.q.append(v)
    def pop(self):
        return self.q.popleft() if self.q else None
    def peek(self):
        return self.q[0] if self.q else None


NONE, STARVED, OUT_FULL, WF_FULL = 0, 1, 2, 3
# wake-need bits (mirrors rust/src/sim/pe.rs `need`)
NW_TOK, NW_SPC, NF_TOK, NF_SPC, N_WF = 1, 2, 4, 8, 16


class Pe:
    def __init__(self, depths, n_groups):
        dw, df, dwf = depths
        self.w_fifo = Fifo(dw)
        self.f_fifo = Fifo(df)
        self.wf_fifo = Fifo(dwf)
        self.w_reg = 0
        self.f_reg = 0
        self.groups_done = 0
        self.n_groups = n_groups
        self.ds_done = n_groups == 0
        self.compute_done = n_groups == 0
        self.mac_ops = 0
        self.finish_ds_cycle = 0

    # returns (fwd_w, fwd_f, progressed, stall, need_mask)
    def ds_step(self, w_space_down, f_space_right, stats):
        if self.ds_done:
            return (None, None, False, NONE, 0)
        if self.w_reg == 0 or self.f_reg == 0:
            return self.fill_regs(w_space_down, f_space_right, stats)

        fwd_w = fwd_f = None
        w, f = self.w_reg, self.f_reg
        w_last, f_last = t_eog(w), t_eog(f)
        aligned = (t_offset(w) == t_offset(f)
                   and not t_placeholder(w) and not t_placeholder(f))

        if aligned and t_tag16(f) and not t_hi(f):
            push_w, push_f, barrier = False, True, False
        elif aligned and t_tag16(w) and not t_hi(w):
            push_w, push_f, barrier = True, False, False
        elif w_last and f_last:
            push_w, push_f, barrier = True, True, True
        elif w_last:
            push_w, push_f, barrier = False, True, False
        elif f_last:
            push_w, push_f, barrier = True, False, False
        elif t_offset(w) == t_offset(f):
            push_w, push_f, barrier = True, True, False
        elif t_offset(w) < t_offset(f):
            push_w, push_f, barrier = True, False, False
        else:
            push_w, push_f, barrier = False, True, False

        if aligned and not self.wf_fifo.has_space():
            stats['stall_wf_full'] += 1
            return (None, None, False, WF_FULL, N_WF)
        final_barrier = barrier and self.groups_done + 1 == self.n_groups
        if not final_barrier:
            if push_w and (self.w_fifo.is_empty() or not w_space_down):
                if self.w_fifo.is_empty():
                    stats['stall_starved'] += 1
                    return (None, None, False, STARVED, NW_TOK)
                stats['stall_out_full'] += 1
                return (None, None, False, OUT_FULL, NW_SPC)
            if push_f and (self.f_fifo.is_empty() or not f_space_right):
                if self.f_fifo.is_empty():
                    stats['stall_starved'] += 1
                    return (None, None, False, STARVED, NF_TOK)
                stats['stall_out_full'] += 1
                return (None, None, False, OUT_FULL, NF_SPC)

        if aligned:
            ops = 2 if (t_tag16(w) and t_hi(w) and t_tag16(f) and t_hi(f)) else 1
            self.wf_fifo.push(ops)
            stats['pairs'] += 1
            stats['mac_ops'] += ops
            self.mac_ops += ops

        if barrier:
            self.groups_done += 1
            stats['barrier_cycles'] += 1
            if final_barrier:
                self.w_reg = 0
                self.f_reg = 0
                self.ds_done = True
                return (None, None, True, NONE, 0)
        if push_w:
            fwd_w = self.try_load_w(w_space_down)
            assert fwd_w is not None
        if push_f:
            fwd_f = self.try_load_f(f_space_right)
            assert fwd_f is not None
        return (fwd_w, fwd_f, True, NONE, 0)

    def fill_regs(self, w_space_down, f_space_right, stats):
        fwd_w = fwd_f = None
        needs = 0
        if self.w_reg == 0:
            fwd_w = self.try_load_w(w_space_down)
            if fwd_w is None:
                needs |= NW_TOK | NW_SPC
        if self.f_reg == 0:
            fwd_f = self.try_load_f(f_space_right)
            if fwd_f is None:
                needs |= NF_TOK | NF_SPC
        if needs:
            stats['stall_starved'] += 1
        progressed = fwd_w is not None or fwd_f is not None
        return (fwd_w, fwd_f, progressed, STARVED if needs else NONE, needs)

    def try_load_w(self, space_down):
        if self.w_fifo.is_empty() or not space_down:
            return None
        t = self.w_fifo.pop()
        self.w_reg = t
        return t

    def try_load_f(self, space_right):
        if self.f_fifo.is_empty() or not space_right:
            return None
        t = self.f_fifo.pop()
        self.f_reg = t
        return t

    def mac_step(self, ds_cycle, stats):
        if self.compute_done:
            return
        ops = self.wf_fifo.peek()
        if ops is not None:
            self.wf_fifo.pop()
            if ops > 1:
                self.wf_fifo.push(ops - 1)
        else:
            if self.ds_done:
                self.compute_done = True
                self.finish_ds_cycle = ds_cycle
            else:
                stats['mac_idle'] += 1


def new_stats():
    return dict(ds_cycles=0, mac_ops=0, pairs=0, token_pushes=0,
                stall_wf_full=0, stall_out_full=0, stall_starved=0,
                mac_idle=0, f_tokens=0, w_tokens=0, barrier_cycles=0)

CYCLE_LIMIT = 2_000_000


def reference(f_src, w_src, n_groups, rows, cols, depths, ratio):
    stats = new_stats()
    f_idx = [0] * rows
    w_idx = [0] * cols
    pes = [Pe(depths, n_groups) for _ in range(rows * cols)]
    ds_cycle = 0
    mac_countdown = ratio
    remaining = rows * cols
    while remaining > 0:
        for r in range(rows):
            if f_idx[r] < len(f_src[r]) and pes[r * cols].f_fifo.has_space():
                pes[r * cols].f_fifo.push(f_src[r][f_idx[r]])
                f_idx[r] += 1
                stats['f_tokens'] += 1
        for c in range(cols):
            if w_idx[c] < len(w_src[c]) and pes[c].w_fifo.has_space():
                pes[c].w_fifo.push(w_src[c][w_idx[c]])
                w_idx[c] += 1
                stats['w_tokens'] += 1

        idx = rows * cols
        for r in reversed(range(rows)):
            for c in reversed(range(cols)):
                idx -= 1
                if pes[idx].ds_done:
                    continue
                down_ok = r + 1 >= rows or pes[idx + cols].w_fifo.has_space()
                right_ok = c + 1 >= cols or pes[idx + 1].f_fifo.has_space()
                fwd_w, fwd_f, _, _, _ = pes[idx].ds_step(down_ok, right_ok, stats)
                if fwd_w is not None and r + 1 < rows:
                    pes[idx + cols].w_fifo.push(fwd_w)
                    stats['token_pushes'] += 1
                if fwd_f is not None and c + 1 < cols:
                    pes[idx + 1].f_fifo.push(fwd_f)
                    stats['token_pushes'] += 1

        mac_countdown -= 1
        if mac_countdown == 0:
            mac_countdown = ratio
            for pe in pes:
                was = pe.compute_done
                pe.mac_step(ds_cycle, stats)
                if pe.compute_done and not was:
                    remaining -= 1

        ds_cycle += 1
        if ds_cycle > CYCLE_LIMIT:
            raise RuntimeError("reference deadlock")

    max_drain = 0
    for c in range(cols):
        t = 0
        for r in range(rows):
            fin = pes[r * cols + c].finish_ds_cycle // ratio + 1
            t = max(t + 1, fin + 1)
        max_drain = max(max_drain, t)
    stats['ds_cycles'] = max(ds_cycle, max_drain * ratio)
    return stats


def event(f_src, w_src, n_groups, rows, cols, depths, ratio):
    """Bitset worklist + precise-need wakes (mirrors sim/array.rs)."""
    stats = new_stats()
    n = rows * cols
    words = (n + 63) // 64
    pes = [Pe(depths, n_groups) for _ in range(n)]
    f_idx = [0] * rows
    w_idx = [0] * cols
    live_rows = list(range(rows))
    live_cols = list(range(cols))
    cur = [0] * words
    nxt = [0] * words
    park_cat = [NONE] * n
    park_need = [0] * n
    wf_busy = []
    finishing = []
    counts = [0, 0, 0, 0]
    fresh = [0, 0, 0, 0]
    n_mac_idle = n
    remaining = n
    ds_cycle = 0
    mac_countdown = ratio

    def wake(bits, j, ev):
        if park_cat[j] != NONE and not (park_need[j] & ev):
            return
        bits[j >> 6] |= 1 << (j & 63)

    for i in range(n):
        cur[i >> 6] |= 1 << (i & 63)

    while remaining > 0:
        # 1. injection
        ri = 0
        while ri < len(live_rows):
            r = live_rows[ri]
            edge = r * cols
            if pes[edge].f_fifo.has_space():
                pes[edge].f_fifo.push(f_src[r][f_idx[r]])
                f_idx[r] += 1
                stats['f_tokens'] += 1
                wake(cur, edge, NF_TOK)
                if f_idx[r] == len(f_src[r]):
                    live_rows[ri] = live_rows[-1]
                    live_rows.pop()
                    continue
            ri += 1
        ci = 0
        while ci < len(live_cols):
            c = live_cols[ci]
            if pes[c].w_fifo.has_space():
                pes[c].w_fifo.push(w_src[c][w_idx[c]])
                w_idx[c] += 1
                stats['w_tokens'] += 1
                wake(cur, c, NW_TOK)
                if w_idx[c] == len(w_src[c]):
                    live_cols[ci] = live_cols[-1]
                    live_cols.pop()
                    continue
            ci += 1

        # 2. DS scan: highest set bit first (reverse raster order)
        wi = words
        while wi > 0:
            wi -= 1
            while cur[wi]:
                b = cur[wi].bit_length() - 1
                cur[wi] &= ~(1 << b)
                i = (wi << 6) + b
                cat = park_cat[i]
                if cat != NONE:
                    counts[cat] -= 1
                    park_cat[i] = NONE
                if pes[i].ds_done:
                    continue
                first_col = i % cols == 0
                last_col = i % cols == cols - 1
                down_ok = i + cols >= n or pes[i + cols].w_fifo.has_space()
                right_ok = last_col or pes[i + 1].f_fifo.has_space()
                wf_was_empty = pes[i].wf_fifo.is_empty()
                fwd_w, fwd_f, progressed, stall, needm = \
                    pes[i].ds_step(down_ok, right_ok, stats)
                if fwd_w is not None:
                    if i >= cols:
                        wake(cur, i - cols, NW_SPC)
                    if i + cols < n:
                        pes[i + cols].w_fifo.push(fwd_w)
                        stats['token_pushes'] += 1
                        wake(nxt, i + cols, NW_TOK)
                if fwd_f is not None:
                    if not first_col:
                        wake(cur, i - 1, NF_SPC)
                    if not last_col:
                        pes[i + 1].f_fifo.push(fwd_f)
                        stats['token_pushes'] += 1
                        wake(nxt, i + 1, NF_TOK)
                if wf_was_empty and not pes[i].wf_fifo.is_empty():
                    n_mac_idle -= 1
                    wf_busy.append(i)
                if pes[i].ds_done:
                    if pes[i].wf_fifo.is_empty():
                        n_mac_idle -= 1
                        finishing.append(i)
                elif progressed:
                    nxt[wi] |= 1 << b
                else:
                    assert stall != NONE
                    park_cat[i] = stall
                    park_need[i] = needm
                    fresh[stall] += 1

        # 3. parked accrual + fold fresh parks
        stats['stall_starved'] += counts[STARVED]
        stats['stall_out_full'] += counts[OUT_FULL]
        stats['stall_wf_full'] += counts[WF_FULL]
        for k in (1, 2, 3):
            counts[k] += fresh[k]
            fresh[k] = 0

        # 4. MAC tick
        mac_countdown -= 1
        if mac_countdown == 0:
            mac_countdown = ratio
            stats['mac_idle'] += n_mac_idle
            for j in finishing:
                pes[j].compute_done = True
                pes[j].finish_ds_cycle = ds_cycle
                remaining -= 1
            finishing.clear()
            k = 0
            while k < len(wf_busy):
                j = wf_busy[k]
                ops = pes[j].wf_fifo.pop()
                if ops > 1:
                    pes[j].wf_fifo.push(ops - 1)
                if park_cat[j] == WF_FULL:
                    nxt[j >> 6] |= 1 << (j & 63)
                if pes[j].wf_fifo.is_empty():
                    wf_busy[k] = wf_busy[-1]
                    wf_busy.pop()
                    if pes[j].ds_done:
                        finishing.append(j)
                    else:
                        n_mac_idle += 1
                else:
                    k += 1

        ds_cycle += 1
        if ds_cycle > CYCLE_LIMIT:
            raise RuntimeError("event overrun")
        if remaining == 0:
            break

        # 5. skip-ahead when globally stalled
        if not any(nxt):
            injectable = any(pes[r * cols].f_fifo.has_space() for r in live_rows) \
                or any(pes[c].w_fifo.has_space() for c in live_cols)
            if not injectable:
                if not wf_busy and not finishing:
                    raise RuntimeError("event deadlock")
                skip = mac_countdown - 1
                if skip > 0:
                    stats['stall_starved'] += skip * counts[STARVED]
                    stats['stall_out_full'] += skip * counts[OUT_FULL]
                    stats['stall_wf_full'] += skip * counts[WF_FULL]
                    ds_cycle += skip
                    mac_countdown = 1

        # cur is drained: swap with the queued next-cycle set
        cur, nxt = nxt, cur

    max_drain = 0
    for c in range(cols):
        t = 0
        for r in range(rows):
            fin = pes[r * cols + c].finish_ds_cycle // ratio + 1
            t = max(t + 1, fin + 1)
        max_drain = max(max_drain, t)
    stats['ds_cycles'] = max(ds_cycle, max_drain * ratio)
    return stats


def gen_stream(rng, n_groups, density, p16, kernel):
    toks = []
    for g in range(n_groups):
        start = len(toks)
        off = 0
        while off < 16:
            if rng.random() < density:
                v = rng.randrange(1, 128)
                if rng.random() < p16:
                    toks.append(tok(v, off, tag16=True, hi=False))
                    toks.append(tok(rng.randrange(1, 128), off, tag16=True, hi=True))
                else:
                    toks.append(tok(v, off))
            off += 1
        if len(toks) == start:
            toks.append(tok(0, 0, eog=True))
        else:
            toks[-1] |= EOG
    if kernel and toks:
        toks[-1] |= EOK
    return toks


def run_fuzz(cases=400, seed=7):
    rng = random.Random(seed)
    for case in range(cases):
        rows = rng.randrange(1, 6)
        cols = rng.randrange(1, 6)
        n_groups = rng.randrange(1, 5)
        density = rng.choice([0.1, 0.3, 0.5, 0.8, 1.0])
        p16 = rng.choice([0.0, 0.0, 0.2])
        depth = rng.choice([1, 2, 4, 8, INF])
        depths = (depth, depth, depth)
        ratio = rng.choice([1, 2, 4, 8])
        f_src = [gen_stream(rng, n_groups, density, p16, False) for _ in range(rows)]
        w_src = [gen_stream(rng, n_groups, density, p16, True) for _ in range(cols)]
        a = reference(f_src, w_src, n_groups, rows, cols, depths, ratio)
        b = event(f_src, w_src, n_groups, rows, cols, depths, ratio)
        if a != b:
            diff = {k: (a[k], b[k]) for k in a if a[k] != b[k]}
            print(f"case {case} DIVERGED rows={rows} cols={cols} groups={n_groups} "
                  f"density={density} p16={p16} depth={depth} ratio={ratio}")
            print("  diff:", diff)
            return False
    print(f"all {cases} fuzz cases bit-identical")
    return True


if __name__ == "__main__":
    ok = run_fuzz(400, 7)
    ok = run_fuzz(400, 1234) and ok
    raise SystemExit(0 if ok else 1)
